//! Versioned model snapshots: the serialised form of a paused
//! nested-batch run.
//!
//! A snapshot is a single JSON document (built on `util::json`, the same
//! machinery as the artifact manifest) holding the [`RunConfig`], the
//! complete [`NestedState`] — centroids with cached norms/displacements,
//! exact sufficient statistics, per-point assignments and the batch
//! cursor — the RNG stream, and (optionally) the training data buffer.
//!
//! **Bit-exactness.** JSON numbers are f64, which silently corrupts
//! f32/f64 bit patterns and 64-bit integers; every binary payload
//! therefore travels as a hex blob of its little-endian bytes
//! (`util::json::hex_encode`). `save → load` reproduces every float and
//! every counter to the bit, which is what makes `resume` retrace the
//! uninterrupted run exactly (tested in `tests/serve.rs`).
//!
//! Layout (version 1):
//!
//! ```json
//! {"format": "nmbkm-snapshot", "version": 1,
//!  "config": { ... RunConfig ... },
//!  "k": 50, "d": 784, "n": 60000, "b": 10000, "b_prev": 10000,
//!  "rounds": 12,
//!  "centroids": "<hex f32 k*d>", "cent_norms": "<hex f32 k>",
//!  "cent_p": "<hex f32 k>",
//!  "stats_s": "<hex f64 k*d>", "stats_v": "<hex f64 k>",
//!  "stats_sse": "<hex f64 k>",
//!  "labels": "<hex u32 n>", "dist2": "<hex f32 n>",
//!  "seen_mask": "<hex bitset n>",
//!  "rng_state": ["<hex u64>", ...4], "rng_spare": null,
//!  "data": {"kind": "dense"|"sparse", ...}}
//! ```
//!
//! **Binary sidecar (version 2).** Hex blobs double the artifact size,
//! which replication pays on every snapshot ship and the WAL on every
//! checkpoint. The binary format keeps the scalar/config fields as a
//! small JSON header (same parser, same validation) and stores every
//! blob as raw little-endian bytes, with the data section reusing the
//! [`serve::wire`](crate::serve::wire) row codec — ≈ 0.5x the hex-JSON
//! size, still fully deterministic (byte-identical round-trips).
//! [`Snapshot::load`]/[`Snapshot::from_bytes`] sniff the leading magic,
//! so every reader accepts both formats transparently:
//!
//! ```text
//! magic "NMBKMSB1" (8 B) | u32 header_len | header JSON |
//! centroids k·d f32 | cent_norms k f32 | cent_p k f32 |
//! stats_s k·d f64 | stats_v k f64 | stats_sse k f64 |
//! labels n u32 | dist2 n f32 | seen_mask ceil(n/8) bytes |
//! [ data? u64 payload_len | encode_rows payload (n rows) ]
//! ```
//!
//! Every section length is derived from the validated header scalars
//! with checked arithmetic and compared against the remaining mapped
//! length **before** any allocation — hostile documents fail cleanly.

use crate::config::RunConfig;
use crate::data::{Data, Storage};
use crate::kmeans::state::{Assignments, Centroids, SuffStats, UNASSIGNED};
use crate::kmeans::NestedState;
use crate::linalg::dense::DenseMatrix;
use crate::linalg::sparse::CsrMatrix;
use crate::serve::wire;
use crate::util::json::{self, hex_decode, hex_encode, Json};
use crate::util::rng::Pcg64;
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::io::Write;
use std::path::Path;

/// Current JSON snapshot format version; bumped on incompatible changes.
pub const SNAPSHOT_VERSION: usize = 1;
/// Binary sidecar format version (the header's `version` field).
pub const BINARY_SNAPSHOT_VERSION: usize = 2;
/// Leading magic of a binary snapshot ("NMBKM Snapshot Binary v1").
pub const BINARY_MAGIC: &[u8; 8] = b"NMBKMSB1";

/// On-disk snapshot encoding. JSON is the v1 interchange format (hex
/// blobs, diffable, backwards-compatible); binary is the compact
/// sidecar the WAL/replication layer ships.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SnapshotFormat {
    #[default]
    Json,
    Binary,
}

impl SnapshotFormat {
    pub fn parse(s: &str) -> Result<SnapshotFormat> {
        match s {
            "json" => Ok(SnapshotFormat::Json),
            "binary" | "bin" => Ok(SnapshotFormat::Binary),
            other => bail!("unknown snapshot format '{other}' (json | binary)"),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            SnapshotFormat::Json => "json",
            SnapshotFormat::Binary => "binary",
        }
    }

    /// File extension snapshots of this format are written under.
    pub fn ext(self) -> &'static str {
        match self {
            SnapshotFormat::Json => "json",
            SnapshotFormat::Binary => "bin",
        }
    }
}

/// A complete, versioned model artifact: everything needed to answer
/// `predict` queries, and — when the data section is included — to
/// resume training exactly where it paused.
#[derive(Clone, Debug)]
pub struct Snapshot {
    pub cfg: RunConfig,
    pub state: NestedState,
    pub rng: Pcg64,
    /// Rounds completed before the snapshot (continues trace numbering).
    pub rounds: usize,
    /// Training buffer; `None` makes a smaller predict-only artifact.
    pub data: Option<Data>,
}

impl Snapshot {
    /// The model itself (for predict-only consumers).
    pub fn centroids(&self) -> &Centroids {
        &self.state.cent
    }

    pub fn to_json(&self) -> Json {
        let st = &self.state;
        let (rng_words, rng_spare) = self.rng.to_parts();
        let mut fields = vec![
            ("format", json::s("nmbkm-snapshot")),
            ("version", json::num(SNAPSHOT_VERSION as f64)),
            ("config", self.cfg.to_json()),
            ("k", json::num(st.cent.k() as f64)),
            ("d", json::num(st.cent.d() as f64)),
            ("n", json::num(st.n as f64)),
            ("b", json::num(st.b as f64)),
            ("b_prev", json::num(st.b_prev as f64)),
            ("rounds", json::num(self.rounds as f64)),
            ("centroids", json::s(&f32s_to_hex(&st.cent.c.data))),
            ("cent_norms", json::s(&f32s_to_hex(&st.cent.norms))),
            ("cent_p", json::s(&f32s_to_hex(&st.cent.p))),
            ("stats_s", json::s(&f64s_to_hex(&st.stats.s))),
            ("stats_v", json::s(&f64s_to_hex(&st.stats.v))),
            ("stats_sse", json::s(&f64s_to_hex(&st.stats.sse))),
            ("labels", json::s(&u32s_to_hex(&st.assign.label))),
            ("dist2", json::s(&f32s_to_hex(&st.assign.dist2))),
            ("seen_mask", json::s(&hex_encode(&seen_mask(&st.assign.label)))),
            (
                "rng_state",
                Json::Arr(
                    rng_words
                        .iter()
                        .map(|w| json::s(&format!("{w:x}")))
                        .collect(),
                ),
            ),
            (
                "rng_spare",
                match rng_spare {
                    Some(x) => json::s(&format!("{:x}", x.to_bits())),
                    None => Json::Null,
                },
            ),
        ];
        if let Some(data) = &self.data {
            fields.push(("data", data_to_json(data)));
        }
        json::obj(fields)
    }

    pub fn from_json(v: &Json) -> Result<Snapshot> {
        ensure!(
            v.get("format").and_then(Json::as_str) == Some("nmbkm-snapshot"),
            "not an nmbkm snapshot (missing format tag)"
        );
        let version = req_usize(v, "version")?;
        ensure!(
            version == SNAPSHOT_VERSION,
            "snapshot version {version} unsupported (this build reads \
             version {SNAPSHOT_VERSION})"
        );
        let cfg = RunConfig::from_json(
            v.get("config").ok_or_else(|| anyhow!("snapshot missing config"))?,
        )
        .map_err(|e| anyhow!("snapshot config: {e}"))?;

        let k = req_usize(v, "k")?;
        let d = req_usize(v, "d")?;
        let n = req_usize(v, "n")?;
        let b = req_usize(v, "b")?;
        let b_prev = req_usize(v, "b_prev")?;
        let rounds = req_usize(v, "rounds")?;
        ensure!(b_prev <= b && b <= n, "bad batch cursor: b_prev={b_prev} b={b} n={n}");
        ensure!(k >= 1 && d >= 1, "bad model shape k={k} d={d}");
        let kd = count_mul(k, d, "centroid")?;

        let c = blob_f32(v, "centroids", kd)?;
        let norms = blob_f32(v, "cent_norms", k)?;
        let p = blob_f32(v, "cent_p", k)?;
        let s = blob_f64(v, "stats_s", kd)?;
        let sv = blob_f64(v, "stats_v", k)?;
        let sse = blob_f64(v, "stats_sse", k)?;
        let labels = blob_u32(v, "labels", n)?;
        let dist2 = blob_f32(v, "dist2", n)?;

        // integrity: the usage mask must match both the stored labels and
        // the batch cursor (points are used iff they sit in the seen
        // prefix — the each-point-counts-exactly-once invariant)
        let mask = hex_field(v, "seen_mask")?;
        check_mask_integrity(&mask, &labels, k, n, b_prev)?;

        let (words, spare) = rng_from_json(v)?;

        let data = match v.get("data") {
            None | Some(Json::Null) => None,
            Some(dv) => {
                let data = data_from_json(dv)?;
                ensure!(
                    data.n() == n && data.dim() == d,
                    "data section is {}x{} but the state says {n}x{d}",
                    data.n(),
                    data.dim()
                );
                Some(data)
            }
        };

        Ok(Snapshot {
            cfg,
            state: NestedState {
                cent: Centroids::from_parts(
                    DenseMatrix::from_vec(k, d, c),
                    norms,
                    p,
                ),
                stats: SuffStats::from_parts(k, d, s, sv, sse),
                assign: Assignments::from_parts(labels, dist2),
                b_prev,
                b,
                n,
            },
            rng: Pcg64::from_parts(words, spare),
            rounds,
            data,
        })
    }

    /// Write atomically (temp file + rename) so a crash mid-save never
    /// leaves a torn artifact behind. Streams through
    /// [`write_snapshot`], so the document (and its 2x-size hex blobs)
    /// never materialise in memory.
    pub fn save(&self, path: &Path) -> Result<()> {
        self.save_as(path, SnapshotFormat::Json)
    }

    /// [`Snapshot::save`] with an explicit on-disk format.
    pub fn save_as(&self, path: &Path, format: SnapshotFormat) -> Result<()> {
        save_parts_as(
            &self.cfg,
            &self.state,
            &self.rng,
            self.rounds,
            self.data.as_ref(),
            path,
            format,
        )
    }

    /// Decode a snapshot from raw bytes, sniffing the format: a leading
    /// [`BINARY_MAGIC`] selects the binary reader, anything else is
    /// parsed as a v1 JSON document. This is the single entry every
    /// byte-source goes through (files, WAL checkpoints, follower
    /// bootstrap bodies).
    pub fn from_bytes(bytes: &[u8]) -> Result<Snapshot> {
        if bytes.starts_with(BINARY_MAGIC) {
            return Self::from_binary(bytes);
        }
        let text = std::str::from_utf8(bytes)
            .map_err(|_| anyhow!("snapshot is neither binary (bad magic) nor UTF-8 JSON"))?;
        let v = Json::parse(text).map_err(|e| anyhow!("snapshot: {e}"))?;
        Self::from_json(&v)
    }

    pub fn load(path: &Path) -> Result<Snapshot> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading snapshot {}", path.display()))?;
        Self::from_bytes(&bytes)
            .map_err(|e| anyhow!("snapshot {}: {e:#}", path.display()))
    }

    /// Parse the binary sidecar format. Mirrors [`Snapshot::from_json`]
    /// exactly — same header validation (via the JSON header), same
    /// integrity checks, same constructors — so both readers accept and
    /// reject identically.
    fn from_binary(bytes: &[u8]) -> Result<Snapshot> {
        ensure!(bytes.len() >= 12, "binary snapshot shorter than its preamble");
        ensure!(bytes.starts_with(BINARY_MAGIC), "bad binary snapshot magic");
        let header_len =
            u32::from_le_bytes(bytes[8..12].try_into().unwrap()) as usize;
        // the declared header length must fit the mapped bytes before
        // anything is sliced or allocated from it
        ensure!(
            header_len <= bytes.len() - 12,
            "binary snapshot header claims {header_len} bytes, {} remain",
            bytes.len() - 12
        );
        let header = std::str::from_utf8(&bytes[12..12 + header_len])
            .map_err(|_| anyhow!("binary snapshot header is not UTF-8"))?;
        let v = Json::parse(header)
            .map_err(|e| anyhow!("binary snapshot header: {e}"))?;
        ensure!(
            v.get("format").and_then(Json::as_str) == Some("nmbkm-snapshot"),
            "not an nmbkm snapshot (missing format tag)"
        );
        let version = req_usize(&v, "version")?;
        ensure!(
            version == BINARY_SNAPSHOT_VERSION,
            "binary snapshot version {version} unsupported (this build reads \
             version {BINARY_SNAPSHOT_VERSION})"
        );
        let cfg = RunConfig::from_json(
            v.get("config").ok_or_else(|| anyhow!("snapshot missing config"))?,
        )
        .map_err(|e| anyhow!("snapshot config: {e}"))?;
        let k = req_usize(&v, "k")?;
        let d = req_usize(&v, "d")?;
        let n = req_usize(&v, "n")?;
        let b = req_usize(&v, "b")?;
        let b_prev = req_usize(&v, "b_prev")?;
        let rounds = req_usize(&v, "rounds")?;
        ensure!(b_prev <= b && b <= n, "bad batch cursor: b_prev={b_prev} b={b} n={n}");
        ensure!(k >= 1 && d >= 1, "bad model shape k={k} d={d}");
        let kd = count_mul(k, d, "centroid")?;
        let data_kind = match v.get("data").and_then(Json::as_str) {
            None => None,
            Some("dense") => Some(false),
            Some("sparse") => Some(true),
            Some(other) => bail!("unknown data kind {other:?}"),
        };

        // fixed-section byte budget, checked before any allocation: a
        // hostile n/k/d must fail here, not wrap or OOM below
        let body = &bytes[12 + header_len..];
        let mask_len = n.div_ceil(8);
        let mut need = 0usize;
        for (count, width) in [
            (kd, 4),      // centroids
            (k, 4),       // cent_norms
            (k, 4),       // cent_p
            (kd, 8),      // stats_s
            (k, 8),       // stats_v
            (k, 8),       // stats_sse
            (n, 4),       // labels
            (n, 4),       // dist2
            (mask_len, 1) // seen_mask
        ] {
            need = need
                .checked_add(count_mul(count, width, "section")?)
                .ok_or_else(|| anyhow!("binary snapshot section sizes overflow"))?;
        }
        ensure!(
            need <= body.len(),
            "binary snapshot declares {need} section bytes, {} remain",
            body.len()
        );

        let mut at = 0usize;
        let c = take_f32s(body, &mut at, kd)?;
        let norms = take_f32s(body, &mut at, k)?;
        let p = take_f32s(body, &mut at, k)?;
        let s = take_f64s(body, &mut at, kd)?;
        let sv = take_f64s(body, &mut at, k)?;
        let sse = take_f64s(body, &mut at, k)?;
        let labels = take_u32s(body, &mut at, n)?;
        let dist2 = take_f32s(body, &mut at, n)?;
        let mask = take_bytes(body, &mut at, mask_len)?;
        check_mask_integrity(mask, &labels, k, n, b_prev)?;

        let (words, spare) = rng_from_json(&v)?;

        let data = match data_kind {
            None => None,
            Some(sparse) => {
                let len_bytes = take_bytes(body, &mut at, 8)?;
                let payload_len =
                    u64::from_le_bytes(len_bytes.try_into().unwrap());
                ensure!(
                    payload_len as usize as u64 == payload_len
                        && payload_len as usize <= body.len() - at,
                    "binary snapshot data section claims {payload_len} bytes, \
                     {} remain",
                    body.len() - at
                );
                let payload = take_bytes(body, &mut at, payload_len as usize)?;
                let rows = wire::decode_rows(payload)
                    .map_err(|e| anyhow!("binary snapshot data section: {e:#}"))?;
                ensure!(
                    rows.len() == n,
                    "data section holds {} rows but the state says {n}",
                    rows.len()
                );
                // assemble rebuilds exactly what the live ingest path
                // builds (norms recomputed, same as the JSON reader)
                let data = wire::assemble(&rows, d, sparse)
                    .map_err(|e| anyhow!("binary snapshot data section: {e:#}"))?;
                Some(data)
            }
        };
        ensure!(
            at == body.len(),
            "binary snapshot has {} trailing bytes",
            body.len() - at
        );

        Ok(Snapshot {
            cfg,
            state: NestedState {
                cent: Centroids::from_parts(
                    DenseMatrix::from_vec(k, d, c),
                    norms,
                    p,
                ),
                stats: SuffStats::from_parts(k, d, s, sv, sse),
                assign: Assignments::from_parts(labels, dist2),
                b_prev,
                b,
                n,
            },
            rng: Pcg64::from_parts(words, spare),
            rounds,
            data,
        })
    }
}

/// Serialise a snapshot's parts as JSON **directly to the writer**:
/// nothing larger than an 8 KB hex buffer is materialised, and the data
/// section streams from the (borrowed) live buffer. The previous path
/// cloned the data buffer into an owned [`Snapshot`] and then built the
/// whole document string — a transient 3–4x memory spike on large
/// models. Output is byte-identical to `Snapshot::to_json().to_string()`
/// (keys in the same sorted order, same number/hex formats; tested), so
/// both paths produce interchangeable, stable artifacts.
pub fn write_snapshot<W: Write>(
    cfg: &RunConfig,
    state: &NestedState,
    rng: &Pcg64,
    rounds: usize,
    data: Option<&Data>,
    w: &mut W,
) -> Result<()> {
    let st = state;
    let (rng_words, rng_spare) = rng.to_parts();
    // keys in BTreeMap (lexicographic) order to match Json::to_string
    write!(w, "{{\"b\":{}", st.b)?;
    write!(w, ",\"b_prev\":{}", st.b_prev)?;
    w.write_all(b",\"cent_norms\":\"")?;
    write_hex_f32s(w, &st.cent.norms)?;
    w.write_all(b"\",\"cent_p\":\"")?;
    write_hex_f32s(w, &st.cent.p)?;
    w.write_all(b"\",\"centroids\":\"")?;
    write_hex_f32s(w, &st.cent.c.data)?;
    w.write_all(b"\",\"config\":")?;
    w.write_all(cfg.to_json().to_string().as_bytes())?;
    write!(w, ",\"d\":{}", st.cent.d())?;
    if let Some(data) = data {
        w.write_all(b",\"data\":")?;
        write_data(w, data)?;
    }
    w.write_all(b",\"dist2\":\"")?;
    write_hex_f32s(w, &st.assign.dist2)?;
    w.write_all(b"\",\"format\":\"nmbkm-snapshot\"")?;
    write!(w, ",\"k\":{}", st.cent.k())?;
    w.write_all(b",\"labels\":\"")?;
    write_hex_u32s(w, &st.assign.label)?;
    write!(w, "\",\"n\":{}", st.n)?;
    match rng_spare {
        Some(x) => write!(w, ",\"rng_spare\":\"{:x}\"", x.to_bits())?,
        None => w.write_all(b",\"rng_spare\":null")?,
    }
    w.write_all(b",\"rng_state\":[")?;
    for (i, word) in rng_words.iter().enumerate() {
        if i > 0 {
            w.write_all(b",")?;
        }
        write!(w, "\"{word:x}\"")?;
    }
    write!(w, "],\"rounds\":{rounds}")?;
    w.write_all(b",\"seen_mask\":\"")?;
    write_hex_bytes(w, seen_mask(&st.assign.label).into_iter())?;
    w.write_all(b"\",\"stats_s\":\"")?;
    write_hex_f64s(w, &st.stats.s)?;
    w.write_all(b"\",\"stats_sse\":\"")?;
    write_hex_f64s(w, &st.stats.sse)?;
    w.write_all(b"\",\"stats_v\":\"")?;
    write_hex_f64s(w, &st.stats.v)?;
    write!(w, "\",\"version\":{SNAPSHOT_VERSION}}}")?;
    Ok(())
}

/// Dispatch a streaming snapshot write to the JSON or binary encoder.
pub fn write_snapshot_as<W: Write>(
    cfg: &RunConfig,
    state: &NestedState,
    rng: &Pcg64,
    rounds: usize,
    data: Option<&Data>,
    format: SnapshotFormat,
    w: &mut W,
) -> Result<()> {
    match format {
        SnapshotFormat::Json => write_snapshot(cfg, state, rng, rounds, data, w),
        SnapshotFormat::Binary => {
            write_snapshot_binary(cfg, state, rng, rounds, data, w)
        }
    }
}

/// Stream the binary sidecar format (module docs show the layout).
/// Deterministic: the header JSON has sorted keys and the sections are
/// written in fixed order, so the same snapshot always produces the same
/// bytes (`save → load → save` round-trips byte-identically; tested).
pub fn write_snapshot_binary<W: Write>(
    cfg: &RunConfig,
    state: &NestedState,
    rng: &Pcg64,
    rounds: usize,
    data: Option<&Data>,
    w: &mut W,
) -> Result<()> {
    let resident;
    let data = match data {
        Some(d) if d.is_sharded() => {
            resident = d.to_resident();
            Some(&resident)
        }
        other => other,
    };
    let st = state;
    let (rng_words, rng_spare) = rng.to_parts();
    let mut fields = vec![
        ("format", json::s("nmbkm-snapshot")),
        ("version", json::num(BINARY_SNAPSHOT_VERSION as f64)),
        ("config", cfg.to_json()),
        ("k", json::num(st.cent.k() as f64)),
        ("d", json::num(st.cent.d() as f64)),
        ("n", json::num(st.n as f64)),
        ("b", json::num(st.b as f64)),
        ("b_prev", json::num(st.b_prev as f64)),
        ("rounds", json::num(rounds as f64)),
        (
            "rng_state",
            Json::Arr(
                rng_words
                    .iter()
                    .map(|x| json::s(&format!("{x:x}")))
                    .collect(),
            ),
        ),
        (
            "rng_spare",
            match rng_spare {
                Some(x) => json::s(&format!("{:x}", x.to_bits())),
                None => Json::Null,
            },
        ),
    ];
    if let Some(data) = data {
        fields.push((
            "data",
            json::s(if data.is_sparse() { "sparse" } else { "dense" }),
        ));
    }
    let header = json::obj(fields).to_string();
    w.write_all(BINARY_MAGIC)?;
    w.write_all(&u32::try_from(header.len())?.to_le_bytes())?;
    w.write_all(header.as_bytes())?;
    write_le_f32s(w, &st.cent.c.data)?;
    write_le_f32s(w, &st.cent.norms)?;
    write_le_f32s(w, &st.cent.p)?;
    write_le_f64s(w, &st.stats.s)?;
    write_le_f64s(w, &st.stats.v)?;
    write_le_f64s(w, &st.stats.sse)?;
    write_le_u32s(w, &st.assign.label)?;
    write_le_f32s(w, &st.assign.dist2)?;
    w.write_all(&seen_mask(&st.assign.label))?;
    if let Some(data) = data {
        let payload = data_payload(data);
        w.write_all(&(payload.len() as u64).to_le_bytes())?;
        w.write_all(&payload)?;
    }
    Ok(())
}

/// Encode the training buffer as one `wire::encode_rows` batch — the
/// binary snapshot's data section. `decode_rows` + `wire::assemble`
/// reconstructs exactly the storage the live ingest path would build.
fn data_payload(data: &Data) -> Vec<u8> {
    let n = data.n();
    match &data.storage {
        Storage::Dense(m) => {
            let mut out = Vec::with_capacity(4 + n * (5 + 4 * m.cols));
            out.extend_from_slice(&(n as u32).to_le_bytes());
            for i in 0..n {
                wire::encode_dense_row_into(&mut out, m.row(i));
            }
            out
        }
        Storage::Sparse(m) => {
            let mut out = Vec::with_capacity(4 + 9 * n + 8 * m.nnz());
            out.extend_from_slice(&(n as u32).to_le_bytes());
            for i in 0..n {
                let (idx, vals) = m.row(i);
                wire::encode_sparse_row_into(&mut out, m.cols, idx, vals);
            }
            out
        }
        Storage::Shard(_) => {
            unreachable!("shard storage materialised by the caller")
        }
    }
}

/// Atomic streaming save (temp file + rename) from borrowed parts.
pub fn save_parts(
    cfg: &RunConfig,
    state: &NestedState,
    rng: &Pcg64,
    rounds: usize,
    data: Option<&Data>,
    path: &Path,
) -> Result<()> {
    save_parts_as(cfg, state, rng, rounds, data, path, SnapshotFormat::Json)
}

/// [`save_parts`] with an explicit on-disk format.
pub fn save_parts_as(
    cfg: &RunConfig,
    state: &NestedState,
    rng: &Pcg64,
    rounds: usize,
    data: Option<&Data>,
    path: &Path,
    format: SnapshotFormat,
) -> Result<()> {
    let tmp = path.with_extension(format!("{}.tmp", format.ext()));
    {
        let file = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        let mut w = std::io::BufWriter::new(file);
        write_snapshot_as(cfg, state, rng, rounds, data, format, &mut w)?;
        w.flush()
            .with_context(|| format!("writing {}", tmp.display()))?;
    }
    std::fs::rename(&tmp, path)
        .with_context(|| format!("renaming into {}", path.display()))?;
    Ok(())
}

/// Data section, keys in sorted order (matches `data_to_json`). A
/// disk-sharded buffer is transiently materialised first — snapshotting
/// with data is the one spill-mode operation that pays a full-buffer
/// copy (see README §Bigger-than-RAM ingestion).
fn write_data<W: Write>(w: &mut W, data: &Data) -> Result<()> {
    let resident;
    let data = if data.is_sharded() {
        resident = data.to_resident();
        &resident
    } else {
        data
    };
    match &data.storage {
        Storage::Dense(m) => {
            write!(w, "{{\"cols\":{},\"kind\":\"dense\",\"rows\":{}", m.cols, m.rows)?;
            w.write_all(b",\"values\":\"")?;
            write_hex_f32s(w, &m.data)?;
            w.write_all(b"\"}")?;
        }
        Storage::Sparse(m) => {
            write!(w, "{{\"cols\":{}", m.cols)?;
            w.write_all(b",\"indices\":\"")?;
            write_hex_u32s(w, &m.indices)?;
            w.write_all(b"\",\"indptr\":\"")?;
            write_hex_bytes(
                w,
                m.indptr
                    .iter()
                    .flat_map(|&p| (p as u64).to_le_bytes()),
            )?;
            write!(w, "\",\"kind\":\"sparse\",\"rows\":{}", m.rows)?;
            w.write_all(b",\"values\":\"")?;
            write_hex_f32s(w, &m.values)?;
            w.write_all(b"\"}")?;
        }
        Storage::Shard(_) => unreachable!("shard storage materialised above"),
    }
    Ok(())
}

/// Stream lowercase hex of a byte iterator through a fixed 8 KB buffer.
fn write_hex_bytes<W: Write>(
    w: &mut W,
    bytes: impl Iterator<Item = u8>,
) -> std::io::Result<()> {
    const HEX: &[u8; 16] = b"0123456789abcdef";
    let mut buf = [0u8; 8192];
    let mut fill = 0usize;
    for b in bytes {
        buf[fill] = HEX[(b >> 4) as usize];
        buf[fill + 1] = HEX[(b & 0xf) as usize];
        fill += 2;
        if fill == buf.len() {
            w.write_all(&buf)?;
            fill = 0;
        }
    }
    w.write_all(&buf[..fill])
}

fn write_hex_f32s<W: Write>(w: &mut W, xs: &[f32]) -> std::io::Result<()> {
    write_hex_bytes(w, xs.iter().flat_map(|x| x.to_le_bytes()))
}

fn write_hex_f64s<W: Write>(w: &mut W, xs: &[f64]) -> std::io::Result<()> {
    write_hex_bytes(w, xs.iter().flat_map(|x| x.to_le_bytes()))
}

fn write_hex_u32s<W: Write>(w: &mut W, xs: &[u32]) -> std::io::Result<()> {
    write_hex_bytes(w, xs.iter().flat_map(|x| x.to_le_bytes()))
}

/// Stream raw little-endian bytes through a fixed 8 KB buffer — the
/// binary counterpart of [`write_hex_bytes`].
fn write_le_bytes<W: Write>(
    w: &mut W,
    bytes: impl Iterator<Item = u8>,
) -> std::io::Result<()> {
    let mut buf = [0u8; 8192];
    let mut fill = 0usize;
    for b in bytes {
        buf[fill] = b;
        fill += 1;
        if fill == buf.len() {
            w.write_all(&buf)?;
            fill = 0;
        }
    }
    w.write_all(&buf[..fill])
}

fn write_le_f32s<W: Write>(w: &mut W, xs: &[f32]) -> std::io::Result<()> {
    write_le_bytes(w, xs.iter().flat_map(|x| x.to_le_bytes()))
}

fn write_le_f64s<W: Write>(w: &mut W, xs: &[f64]) -> std::io::Result<()> {
    write_le_bytes(w, xs.iter().flat_map(|x| x.to_le_bytes()))
}

fn write_le_u32s<W: Write>(w: &mut W, xs: &[u32]) -> std::io::Result<()> {
    write_le_bytes(w, xs.iter().flat_map(|x| x.to_le_bytes()))
}

/// Bit-packed "is this point part of the model" mask (LSB-first).
fn seen_mask(labels: &[u32]) -> Vec<u8> {
    let mut mask = vec![0u8; labels.len().div_ceil(8)];
    for (i, &l) in labels.iter().enumerate() {
        if l != UNASSIGNED {
            mask[i / 8] |= 1u8 << (i % 8);
        }
    }
    mask
}

fn data_to_json(data: &Data) -> Json {
    let resident;
    let data = if data.is_sharded() {
        resident = data.to_resident();
        &resident
    } else {
        data
    };
    match &data.storage {
        Storage::Dense(m) => json::obj(vec![
            ("kind", json::s("dense")),
            ("rows", json::num(m.rows as f64)),
            ("cols", json::num(m.cols as f64)),
            ("values", json::s(&f32s_to_hex(&m.data))),
        ]),
        Storage::Sparse(m) => json::obj(vec![
            ("kind", json::s("sparse")),
            ("rows", json::num(m.rows as f64)),
            ("cols", json::num(m.cols as f64)),
            (
                "indptr",
                json::s(&u64s_to_hex(
                    &m.indptr.iter().map(|&x| x as u64).collect::<Vec<_>>(),
                )),
            ),
            ("indices", json::s(&u32s_to_hex(&m.indices))),
            ("values", json::s(&f32s_to_hex(&m.values))),
        ]),
        Storage::Shard(_) => unreachable!("shard storage materialised above"),
    }
}

fn data_from_json(v: &Json) -> Result<Data> {
    let rows = req_usize(v, "rows")?;
    let cols = req_usize(v, "cols")?;
    match v.get("kind").and_then(Json::as_str) {
        Some("dense") => {
            let values = blob_f32(v, "values", count_mul(rows, cols, "data value")?)?;
            Ok(Data::dense(DenseMatrix::from_vec(rows, cols, values)))
        }
        Some("sparse") => {
            let np = rows
                .checked_add(1)
                .ok_or_else(|| anyhow!("data rows {rows} overflows"))?;
            let indptr: Vec<usize> = blob_u64(v, "indptr", np)?
                .into_iter()
                .map(|x| x as usize)
                .collect();
            let nnz = indptr.last().copied().unwrap_or(0);
            let indices = blob_u32(v, "indices", nnz)?;
            let values = blob_f32(v, "values", nnz)?;
            ensure!(indptr[0] == 0, "indptr must start at 0");
            for w in indptr.windows(2) {
                ensure!(w[0] <= w[1], "indptr must be monotone");
            }
            for &c in &indices {
                ensure!((c as usize) < cols, "column index {c} >= cols {cols}");
            }
            Ok(Data::sparse(CsrMatrix { rows, cols, indptr, indices, values }))
        }
        other => bail!("unknown data kind {other:?}"),
    }
}

/// Shared integrity check: the usage mask must match both the stored
/// labels and the batch cursor (points are used iff they sit in the seen
/// prefix — the each-point-counts-exactly-once invariant), and every
/// assigned label must be a valid cluster. Both snapshot readers route
/// through here so they accept and reject identically.
fn check_mask_integrity(
    mask: &[u8],
    labels: &[u32],
    k: usize,
    n: usize,
    b_prev: usize,
) -> Result<()> {
    ensure!(
        mask.len() == n.div_ceil(8),
        "seen_mask length {} != ceil(n/8) = {}",
        mask.len(),
        n.div_ceil(8)
    );
    for i in 0..n {
        let masked = (mask[i / 8] >> (i % 8)) & 1 == 1;
        let labeled = labels[i] != UNASSIGNED;
        let in_prefix = i < b_prev;
        ensure!(
            masked == labeled && labeled == in_prefix,
            "corrupt snapshot: point {i} mask={masked} labeled={labeled} \
             prefix={in_prefix} (b_prev={b_prev})"
        );
        if labeled {
            ensure!(
                (labels[i] as usize) < k,
                "corrupt snapshot: point {i} label {} >= k={k}",
                labels[i]
            );
        }
    }
    Ok(())
}

/// Parse the RNG fields shared by both snapshot headers.
fn rng_from_json(v: &Json) -> Result<([u64; 4], Option<f64>)> {
    let rng_words = v
        .get("rng_state")
        .and_then(Json::as_arr)
        .ok_or_else(|| anyhow!("snapshot missing rng_state"))?;
    ensure!(rng_words.len() == 4, "rng_state must hold 4 words");
    let mut words = [0u64; 4];
    for (w, x) in words.iter_mut().zip(rng_words) {
        let s = x.as_str().ok_or_else(|| anyhow!("rng word not a string"))?;
        *w = u64::from_str_radix(s, 16)
            .map_err(|_| anyhow!("rng word bad hex '{s}'"))?;
    }
    let spare = match v.get("rng_spare") {
        None | Some(Json::Null) => None,
        Some(x) => {
            let s =
                x.as_str().ok_or_else(|| anyhow!("rng_spare not a string"))?;
            Some(f64::from_bits(
                u64::from_str_radix(s, 16)
                    .map_err(|_| anyhow!("rng_spare bad hex '{s}'"))?,
            ))
        }
    };
    Ok((words, spare))
}

/// Take `len` raw bytes from the binary body, advancing the cursor.
/// Overflow-safe: a hostile length fails cleanly instead of wrapping.
fn take_bytes<'a>(b: &'a [u8], at: &mut usize, len: usize) -> Result<&'a [u8]> {
    let end = at
        .checked_add(len)
        .filter(|&e| e <= b.len())
        .ok_or_else(|| anyhow!("binary snapshot truncated at byte {at}"))?;
    let s = &b[*at..end];
    *at = end;
    Ok(s)
}

fn take_f32s(b: &[u8], at: &mut usize, count: usize) -> Result<Vec<f32>> {
    Ok(take_bytes(b, at, count_mul(count, 4, "f32 section")?)?
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn take_f64s(b: &[u8], at: &mut usize, count: usize) -> Result<Vec<f64>> {
    Ok(take_bytes(b, at, count_mul(count, 8, "f64 section")?)?
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn take_u32s(b: &[u8], at: &mut usize, count: usize) -> Result<Vec<u32>> {
    Ok(take_bytes(b, at, count_mul(count, 4, "u32 section")?)?
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn req_usize(v: &Json, key: &str) -> Result<usize> {
    v.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| anyhow!("snapshot missing numeric field '{key}'"))
}

/// Checked element-count arithmetic: corrupt snapshots carry hostile
/// dimension fields, and `k * d` must reject — not wrap (release) or
/// panic (debug) — before it sizes anything.
fn count_mul(a: usize, b: usize, what: &str) -> Result<usize> {
    a.checked_mul(b)
        .ok_or_else(|| anyhow!("snapshot {what} count {a}*{b} overflows"))
}

fn hex_field(v: &Json, key: &str) -> Result<Vec<u8>> {
    let s = v
        .get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("snapshot missing blob field '{key}'"))?;
    hex_decode(s).ok_or_else(|| anyhow!("snapshot field '{key}': bad hex"))
}

fn f32s_to_hex(xs: &[f32]) -> String {
    let mut bytes = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    hex_encode(&bytes)
}

fn f64s_to_hex(xs: &[f64]) -> String {
    let mut bytes = Vec::with_capacity(xs.len() * 8);
    for x in xs {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    hex_encode(&bytes)
}

fn u32s_to_hex(xs: &[u32]) -> String {
    let mut bytes = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    hex_encode(&bytes)
}

fn u64s_to_hex(xs: &[u64]) -> String {
    let mut bytes = Vec::with_capacity(xs.len() * 8);
    for x in xs {
        bytes.extend_from_slice(&x.to_le_bytes());
    }
    hex_encode(&bytes)
}

/// Decode a hex blob and check it holds exactly `expect` elements of
/// `width` bytes. The byte count uses checked arithmetic: `expect` can
/// be attacker-controlled (e.g. a sparse `nnz` read from the document).
fn blob_bytes(v: &Json, key: &str, expect: usize, width: usize) -> Result<Vec<u8>> {
    let want = count_mul(expect, width, key)?;
    let b = hex_field(v, key)?;
    ensure!(
        b.len() == want,
        "snapshot field '{key}': {} bytes, expected {want}",
        b.len(),
    );
    Ok(b)
}

fn blob_f32(v: &Json, key: &str, expect: usize) -> Result<Vec<f32>> {
    Ok(blob_bytes(v, key, expect, 4)?
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn blob_f64(v: &Json, key: &str, expect: usize) -> Result<Vec<f64>> {
    Ok(blob_bytes(v, key, expect, 8)?
        .chunks_exact(8)
        .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn blob_u32(v: &Json, key: &str, expect: usize) -> Result<Vec<u32>> {
    Ok(blob_bytes(v, key, expect, 4)?
        .chunks_exact(4)
        .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

fn blob_u64(v: &Json, key: &str, expect: usize) -> Result<Vec<u64>> {
    Ok(blob_bytes(v, key, expect, 8)?
        .chunks_exact(8)
        .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algo, Rho};
    use crate::data::gaussian::GaussianMixture;
    use crate::kmeans::{init, state};

    fn tiny_state(n: usize, k: usize, d: usize, seed: u64) -> (Data, NestedState) {
        let data = GaussianMixture::default_spec(k, d).generate(n, seed);
        let cent = init::first_k(&data, k);
        let b_prev = n / 2;
        let mut assign = Assignments::new(n);
        let mut stats = SuffStats::zeros(k, d);
        for i in 0..b_prev {
            let (j, d2) = data.nearest(i, &cent.c, &cent.norms);
            assign.label[i] = j;
            assign.dist2[i] = d2;
            stats.add_point(&data, i, j, d2);
        }
        let st = NestedState { cent, stats, assign, b_prev, b: b_prev, n };
        (data, st)
    }

    fn snap(data: Data, st: NestedState) -> Snapshot {
        Snapshot {
            cfg: RunConfig {
                algo: Algo::TbRho,
                k: st.cent.k(),
                rho: Rho::Finite(7.5),
                ..Default::default()
            },
            state: st,
            rng: Pcg64::new(5, 6),
            rounds: 3,
            data: Some(data),
        }
    }

    #[test]
    fn json_roundtrip_is_bit_exact() {
        let (data, st) = tiny_state(40, 3, 5, 1);
        let s = snap(data, st);
        let text = s.to_json().to_string();
        let back = Snapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.cfg, s.cfg);
        assert_eq!(back.state.cent.c.data, s.state.cent.c.data);
        assert_eq!(back.state.cent.norms, s.state.cent.norms);
        assert_eq!(back.state.cent.p, s.state.cent.p);
        let bits = |xs: &[f64]| xs.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&back.state.stats.s), bits(&s.state.stats.s));
        assert_eq!(bits(&back.state.stats.v), bits(&s.state.stats.v));
        assert_eq!(bits(&back.state.stats.sse), bits(&s.state.stats.sse));
        assert_eq!(back.state.assign.label, s.state.assign.label);
        assert_eq!(back.state.assign.dist2, s.state.assign.dist2);
        assert_eq!(back.state.b_prev, s.state.b_prev);
        assert_eq!(back.rounds, 3);
        assert_eq!(back.rng.to_parts(), s.rng.to_parts());
        // second serialisation is byte-identical (stable key order)
        assert_eq!(back.to_json().to_string(), text);
    }

    #[test]
    fn sparse_data_roundtrip() {
        let mut m = CsrMatrix::empty(6);
        m.push_row(&[(0, 1.5), (4, -2.0)]);
        m.push_row(&[]);
        m.push_row(&[(5, 0.25)]);
        let data = Data::sparse(m);
        let v = data_to_json(&data);
        let back = data_from_json(&v).unwrap();
        match (&back.storage, &data.storage) {
            (Storage::Sparse(a), Storage::Sparse(b)) => assert_eq!(a, b),
            _ => panic!("kind changed"),
        }
        assert_eq!(back.norms, data.norms);
    }

    #[test]
    fn rejects_corruption() {
        let (data, st) = tiny_state(30, 3, 4, 2);
        let s = snap(data, st);
        let good = s.to_json().to_string();
        // version bump
        let bad = good.replace("\"version\":1", "\"version\":99");
        assert!(Snapshot::from_json(&Json::parse(&bad).unwrap()).is_err());
        // wrong format tag
        let bad = good.replace("nmbkm-snapshot", "other-thing");
        assert!(Snapshot::from_json(&Json::parse(&bad).unwrap()).is_err());
        // truncated centroid blob
        let c_hex = f32s_to_hex(&s.state.cent.c.data);
        let bad = good.replace(&c_hex, &c_hex[..c_hex.len() - 8]);
        assert!(Snapshot::from_json(&Json::parse(&bad).unwrap()).is_err());
        // mask inconsistent with the batch cursor
        let mask_hex = hex_encode(&seen_mask(&s.state.assign.label));
        let mut flipped = seen_mask(&s.state.assign.label);
        flipped[0] ^= 1;
        let bad = good.replace(&mask_hex, &hex_encode(&flipped));
        assert!(Snapshot::from_json(&Json::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn corrupt_snapshots_error_cleanly() {
        // fuzz-ish: a table of hostile field mutations over a valid
        // document, plus a byte-poke sweep — every mutant must land in
        // a clean Err (or, for the sweep, at worst a harmless Ok);
        // none may panic, not even via debug-mode overflow
        let (data, st) = tiny_state(30, 3, 4, 6);
        let s = snap(data, st);
        let good = s.to_json().to_string();
        let cases: Vec<(&str, String)> = vec![
            ("version string", good.replace("\"version\":1}", "\"version\":\"one\"}")),
            ("version negative", good.replace("\"version\":1}", "\"version\":-3}")),
            ("k zero", good.replace("\"k\":3", "\"k\":0")),
            ("k float", good.replace("\"k\":3", "\"k\":1e30")),
            // k*d overflows usize — must reject, not wrap
            ("k*d overflow", good.replace("\"k\":3", "\"k\":9223372036854775807")),
            ("d huge", good.replace("\"d\":4", "\"d\":4611686018427387904")),
            // labels/dist2/mask sized n*4: checked width math must trip
            ("n huge", good.replace("\"n\":30", "\"n\":9223372036854775807")),
            ("cursor beyond n", good.replace("\"b\":15", "\"b\":31")),
            ("rng_spare bad hex", good.replace("\"rng_spare\":null", "\"rng_spare\":\"zz\"")),
            ("missing config", good.replace("\"config\"", "\"confog\"")),
            ("data kind garbage", good.replace("\"kind\":\"dense\"", "\"kind\":\"dense2\"")),
            (
                "data rows overflow",
                good.replace("\"rows\":30", "\"rows\":18446744073709551615"),
            ),
        ];
        for (what, text) in &cases {
            assert_ne!(text, &good, "{what}: mutation did not apply");
            if let Ok(v) = Json::parse(text) {
                assert!(
                    Snapshot::from_json(&v).is_err(),
                    "{what}: corrupt document loaded successfully"
                );
            }
        }
        // poke a non-hex byte through the document and truncate it at a
        // stride of offsets: parse or load may fail (almost always), but
        // nothing may panic
        for pos in (0..good.len()).step_by(97) {
            let mut mutant = good.clone().into_bytes();
            mutant[pos] = b'z';
            if let Ok(text) = String::from_utf8(mutant) {
                if let Ok(v) = Json::parse(&text) {
                    let _ = Snapshot::from_json(&v);
                }
            }
            if let Ok(v) = Json::parse(&good[..pos]) {
                let _ = Snapshot::from_json(&v);
            }
        }
    }

    #[test]
    fn streaming_writer_matches_tree_serialisation_exactly() {
        // the streaming path must emit byte-identical documents to
        // to_json().to_string() — dense, sparse, and model-only
        let (data, st) = tiny_state(40, 3, 5, 8);
        let dense_snap = snap(data, st);
        let mut sparse_m = CsrMatrix::empty(5);
        for i in 0..30 {
            sparse_m.push_row(&[(i % 5, 1.0 + i as f32), ((i + 2) % 5, -0.5)]);
        }
        let sparse_data = Data::sparse(sparse_m);
        // same state shape, sparse buffer attached in its place
        let (_, sparse_st) = tiny_state(30, 3, 5, 9);
        let mut sparse_snap = snap(
            GaussianMixture::default_spec(3, 5).generate(30, 9),
            sparse_st,
        );
        sparse_snap.data = Some(sparse_data);
        let mut model_only = snap(
            GaussianMixture::default_spec(3, 5).generate(40, 8),
            tiny_state(40, 3, 5, 8).1,
        );
        model_only.data = None;
        for (tag, s) in [
            ("dense", &dense_snap),
            ("sparse", &sparse_snap),
            ("model-only", &model_only),
        ] {
            let mut streamed = Vec::new();
            write_snapshot(
                &s.cfg,
                &s.state,
                &s.rng,
                s.rounds,
                s.data.as_ref(),
                &mut streamed,
            )
            .unwrap();
            assert_eq!(
                String::from_utf8(streamed).unwrap(),
                s.to_json().to_string(),
                "{tag}: streaming writer diverged from tree serialiser"
            );
        }
    }

    #[test]
    fn save_load_file_roundtrip() {
        let (data, st) = tiny_state(25, 2, 3, 3);
        let s = snap(data, st);
        let path = std::env::temp_dir().join("nmbkm-snapshot-unit-test.json");
        s.save(&path).unwrap();
        let back = Snapshot::load(&path).unwrap();
        assert_eq!(back.to_json().to_string(), s.to_json().to_string());
        std::fs::remove_file(&path).ok();
        assert!(Snapshot::load(&path).is_err(), "missing file is an error");
    }

    #[test]
    fn model_only_snapshot_omits_data() {
        let (_, st) = tiny_state(20, 2, 3, 4);
        let mut s = snap(GaussianMixture::default_spec(2, 3).generate(20, 4), st);
        s.data = None;
        let text = s.to_json().to_string();
        assert!(!text.contains("\"data\""));
        let back = Snapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert!(back.data.is_none());
        assert_eq!(
            back.centroids().c.data,
            s.centroids().c.data,
            "predict-only consumers read centroids"
        );
    }

    #[test]
    fn mse_is_preserved_through_roundtrip() {
        // end-to-end sanity: the reloaded model scores points identically
        let (data, st) = tiny_state(60, 4, 6, 5);
        let s = snap(data.clone(), st);
        let text = s.to_json().to_string();
        let back = Snapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        let a = state::exact_mse(&data, s.centroids());
        let b = state::exact_mse(back.data.as_ref().unwrap(), back.centroids());
        assert_eq!(a.to_bits(), b.to_bits());
    }

    fn to_binary_bytes(s: &Snapshot) -> Vec<u8> {
        let mut out = Vec::new();
        write_snapshot_binary(
            &s.cfg,
            &s.state,
            &s.rng,
            s.rounds,
            s.data.as_ref(),
            &mut out,
        )
        .unwrap();
        out
    }

    #[test]
    fn snapshot_format_parses() {
        assert_eq!(SnapshotFormat::parse("json").unwrap(), SnapshotFormat::Json);
        assert_eq!(SnapshotFormat::parse("bin").unwrap(), SnapshotFormat::Binary);
        assert_eq!(
            SnapshotFormat::parse("binary").unwrap(),
            SnapshotFormat::Binary
        );
        assert!(SnapshotFormat::parse("hex").is_err());
        assert_eq!(SnapshotFormat::Binary.ext(), "bin");
        assert_eq!(SnapshotFormat::Json.name(), "json");
    }

    #[test]
    fn binary_roundtrip_is_byte_identical() {
        // dense, sparse, and model-only snapshots: encode → decode →
        // encode must reproduce the exact bytes, and the decoded state
        // must agree with the JSON serialisation bit-for-bit
        let (data, st) = tiny_state(40, 3, 5, 11);
        let dense_snap = snap(data, st);
        let (_, sparse_st) = tiny_state(30, 3, 5, 12);
        let mut m = CsrMatrix::empty(5);
        for i in 0..30 {
            m.push_row(&[((i % 4) as u32, 1.0 + i as f32), (4, -0.5 - i as f32)]);
        }
        let sparse_snap = snap(Data::sparse(m), sparse_st);
        let mut model_only = snap(
            GaussianMixture::default_spec(3, 5).generate(20, 13),
            tiny_state(20, 3, 5, 13).1,
        );
        model_only.data = None;
        for (tag, s) in [
            ("dense", &dense_snap),
            ("sparse", &sparse_snap),
            ("model-only", &model_only),
        ] {
            let bytes = to_binary_bytes(s);
            assert_eq!(&bytes[..8], BINARY_MAGIC, "{tag}: magic");
            let back = Snapshot::from_bytes(&bytes).unwrap();
            assert_eq!(
                to_binary_bytes(&back),
                bytes,
                "{tag}: second serialisation diverged"
            );
            assert_eq!(
                back.to_json().to_string(),
                s.to_json().to_string(),
                "{tag}: binary reader diverged from the JSON reader"
            );
        }
    }

    #[test]
    fn save_as_binary_and_load_sniffs_format() {
        let (data, st) = tiny_state(25, 2, 3, 16);
        let s = snap(data, st);
        let path = std::env::temp_dir().join("nmbkm-snapshot-unit-test.bin");
        s.save_as(&path, SnapshotFormat::Binary).unwrap();
        let bytes = std::fs::read(&path).unwrap();
        assert_eq!(&bytes[..8], BINARY_MAGIC);
        let back = Snapshot::load(&path).unwrap();
        assert_eq!(back.to_json().to_string(), s.to_json().to_string());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn binary_snapshots_halve_the_artifact() {
        // the acceptance bar: raw LE sections must land at ≤ 0.55x the
        // hex-JSON artifact, dense and sparse alike
        let (data, st) = tiny_state(300, 4, 32, 14);
        let dense = snap(data, st);
        let (_, sparse_st) = tiny_state(300, 4, 32, 17);
        let mut m = CsrMatrix::empty(32);
        for i in 0..300 {
            m.push_row(&[((i % 31) as u32, 1.0 + i as f32), (31, -2.0)]);
        }
        let sparse = snap(Data::sparse(m), sparse_st);
        for (tag, s) in [("dense", &dense), ("sparse", &sparse)] {
            let json_len = s.to_json().to_string().len();
            let bin_len = to_binary_bytes(s).len();
            assert!(
                (bin_len as f64) <= 0.55 * json_len as f64,
                "{tag}: binary {bin_len} B vs json {json_len} B"
            );
        }
    }

    #[test]
    fn corrupt_binary_snapshots_error_cleanly() {
        // the binary twin of corrupt_snapshots_error_cleanly: hostile
        // header mutations, oversized declared lengths, a truncation
        // sweep, and a byte-poke sweep — clean Err (or harmless Ok for
        // pokes in float payloads), never a panic or an OOM-sized alloc
        let (data, st) = tiny_state(30, 3, 4, 15);
        let s = snap(data, st);
        let good = to_binary_bytes(&s);
        let header_len =
            u32::from_le_bytes(good[8..12].try_into().unwrap()) as usize;
        let header =
            std::str::from_utf8(&good[12..12 + header_len]).unwrap().to_string();
        let rebuild = |h: &str| -> Vec<u8> {
            let mut out = Vec::with_capacity(good.len());
            out.extend_from_slice(BINARY_MAGIC);
            out.extend_from_slice(&(h.len() as u32).to_le_bytes());
            out.extend_from_slice(h.as_bytes());
            out.extend_from_slice(&good[12 + header_len..]);
            out
        };
        let cases: Vec<(&str, String)> = vec![
            ("version", header.replace("\"version\":2", "\"version\":7")),
            ("format tag", header.replace("nmbkm-snapshot", "other-thing")),
            ("k zero", header.replace("\"k\":3", "\"k\":0")),
            // k*d and n*width must reject via checked math, not wrap or
            // allocate terabytes
            (
                "k*d overflow",
                header.replace("\"k\":3", "\"k\":9223372036854775807"),
            ),
            (
                "n huge",
                header.replace("\"n\":30", "\"n\":4611686018427387904"),
            ),
            ("n beyond sections", header.replace("\"n\":30", "\"n\":31")),
            ("cursor beyond n", header.replace("\"b\":15", "\"b\":31")),
            (
                "data kind garbage",
                header.replace("\"data\":\"dense\"", "\"data\":\"dense2\""),
            ),
            ("missing config", header.replace("\"config\"", "\"confog\"")),
        ];
        for (what, h) in &cases {
            assert_ne!(h, &header, "{what}: mutation did not apply");
            assert!(
                Snapshot::from_bytes(&rebuild(h)).is_err(),
                "{what}: corrupt document loaded successfully"
            );
        }
        // header length pointing past EOF must fail before slicing
        let mut bad = good.clone();
        bad[8..12].copy_from_slice(&u32::MAX.to_le_bytes());
        assert!(Snapshot::from_bytes(&bad).is_err());
        // a flipped seen_mask bit trips the integrity check; the mask
        // section starts after the fixed sections (k=3, d=4, n=30)
        let kd = 3 * 4;
        let fixed = kd * 4 + 3 * 4 + 3 * 4 + kd * 8 + 3 * 8 + 3 * 8 + 30 * 4 + 30 * 4;
        let mut bad = good.clone();
        bad[12 + header_len + fixed] ^= 1;
        assert!(
            Snapshot::from_bytes(&bad).is_err(),
            "mask flip loaded successfully"
        );
        // every truncation fails cleanly
        for cut in (0..good.len()).step_by(41) {
            assert!(
                Snapshot::from_bytes(&good[..cut]).is_err(),
                "accepted cut at {cut}"
            );
        }
        assert!(Snapshot::from_bytes(&good[..good.len() - 1]).is_err());
        // trailing garbage is rejected
        let mut padded = good.clone();
        padded.push(0);
        assert!(Snapshot::from_bytes(&padded).is_err());
        // byte-poke sweep: no offset may panic
        for pos in (0..good.len()).step_by(31) {
            let mut mutant = good.clone();
            mutant[pos] ^= 0x41;
            let _ = Snapshot::from_bytes(&mutant);
        }
    }
}
