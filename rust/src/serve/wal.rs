//! Durable write-ahead log of state-mutating serve ops, and crash
//! recovery by deterministic replay.
//!
//! Every successful `create`/`ingest`/`step`/`drop` appends one record
//! describing its **actual effect** (e.g. the rounds a time-budgeted
//! step really ran, not the rounds it asked for), so replaying the log
//! into a fresh registry reproduces the registry **bit-identically**:
//! the serving sessions are deterministic (fixed-seed Pcg64 streams,
//! each-point-counts-exactly-once sufficient statistics), which turns
//! "replay the log" into "recompute the exact same bits". Failed ops
//! never reach the log; an op is durable once its append returned (per
//! the fsync policy).
//!
//! On-disk layout, in the WAL directory:
//!
//! ```text
//! wal-<first_seq:016x>.log   segment: 25-byte header, then records
//! manifest.json              checkpoint manifest {version, epoch, models}
//! ckpt-<model>.{json,bin}    per-model snapshot (serve::snapshot format)
//! ```
//!
//! Checkpoint snapshots are written in the log's configured
//! [`SnapshotFormat`] (binary halves checkpoint I/O; see
//! `serve::snapshot`); recovery loads either via the format-sniffing
//! [`Snapshot::load`], so a server restarted with a different
//! `--snapshot-format` still resumes cleanly.
//!
//! Segment header: `b"NMBKMWAL"` | version u8 | epoch u64 | first_seq
//! u64 (LE). Record: `len u32 | crc32(payload) u32 | payload`, payload
//! = `seq u64 | header_len u32 | header JSON | body`. Record headers
//! are compact `util::json` documents (BTreeMap-ordered keys, so the
//! bytes are deterministic); ingest bodies reuse the wire row encoding
//! ([`crate::serve::wire::encode_rows`]).
//!
//! **Checkpoints** rotate to a fresh segment, snapshot every model
//! (with its last applied seq, read under the same session lock), write
//! `manifest.json` atomically, and delete the older segments — recovery
//! then resumes from the snapshots and replays only the live tail.
//! **Recovery** scans segments in seq order, truncates a torn or
//! CRC-corrupt tail record in the *last* segment (anything later is by
//! construction unacknowledged), and hard-errors on interior
//! corruption. The **epoch** in segment headers and the manifest is the
//! failover fence: promotion bumps it, and replication rejects records
//! from a lower (stale-primary) epoch — see `serve::replica`.

use crate::config::{Algo, RunConfig};
use crate::obs;
use crate::serve::registry::ModelRegistry;
use crate::serve::session::OnlineSession;
use crate::serve::snapshot::{Snapshot, SnapshotFormat};
use crate::serve::wire;
use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, ensure, Context, Result};
use std::collections::BTreeSet;
use std::fs::{self, File, OpenOptions};
use std::io::Write;
use std::ops::Range;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant};

/// Segment file magic + format version.
const SEG_MAGIC: &[u8; 8] = b"NMBKMWAL";
const SEG_VERSION: u8 = 1;
/// magic | version | epoch u64 | first_seq u64.
const SEG_HEADER_LEN: usize = 8 + 1 + 8 + 8;
/// Hard cap on one record's payload — matches the frame body cap, so
/// anything the wire accepted fits and a corrupt length prefix cannot
/// trigger a giant allocation.
const MAX_RECORD_BYTES: usize = 1 << 28;
/// Log bytes between automatic checkpoints (overridable per server).
pub const DEFAULT_CHECKPOINT_BYTES: u64 = 64 << 20;
/// Default (and soft target) byte size of one `wal-fetch` response.
pub const DEFAULT_FETCH_BYTES: usize = 1 << 20;
/// Hard cap a client may request per `wal-fetch`.
pub const MAX_FETCH_BYTES: usize = 1 << 26;
const MANIFEST: &str = "manifest.json";

// ── CRC32 (IEEE 802.3, table-driven) ─────────────────────────────────

fn crc_table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// IEEE CRC32 of `data` (the `cksum`-compatible reflected polynomial).
pub fn crc32(data: &[u8]) -> u32 {
    let table = crc_table();
    let mut c = !0u32;
    for &b in data {
        c = table[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    !c
}

// ── u64 ⇄ JSON (hex strings, bit-exact — JSON numbers are f64) ───────

/// A u64 as a lowercase-hex JSON string (seqs and epochs must survive
/// JSON bit-exactly; f64 numbers lose integers above 2^53).
pub fn u64_json(x: u64) -> Json {
    json::s(&format!("{x:x}"))
}

/// Read a hex-string u64 field written by [`u64_json`].
pub fn u64_field(v: &Json, key: &str) -> Result<u64> {
    let s = v
        .get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("missing hex-u64 field '{key}'"))?;
    u64::from_str_radix(s, 16).map_err(|_| anyhow!("field '{key}': bad hex '{s}'"))
}

// ── fsync policy ─────────────────────────────────────────────────────

/// When appends reach the platter: `always` fsyncs every record (an
/// acked op survives kill -9 of the whole host), `interval:<ms>` fsyncs
/// at most once per window (group commit — bounded loss), `never`
/// leaves flushing to the OS (crash-consistent but lossy).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum FsyncPolicy {
    Always,
    Interval(Duration),
    Never,
}

impl FsyncPolicy {
    pub fn parse(s: &str) -> Result<FsyncPolicy> {
        match s {
            "always" => Ok(FsyncPolicy::Always),
            "never" => Ok(FsyncPolicy::Never),
            _ => {
                let ms = s
                    .strip_prefix("interval:")
                    .and_then(|ms| ms.parse::<u64>().ok())
                    .ok_or_else(|| {
                        anyhow!("fsync policy must be always|interval:<ms>|never, got '{s}'")
                    })?;
                Ok(FsyncPolicy::Interval(Duration::from_millis(ms)))
            }
        }
    }
}

// ── record framing ───────────────────────────────────────────────────

/// One decoded log record: a monotone sequence number, the op header
/// (same JSON the wire protocol speaks), and an opaque body (wire-row
/// batch for ingests, empty otherwise).
#[derive(Clone, Debug)]
pub struct WalRecord {
    pub seq: u64,
    pub header: Json,
    pub body: Vec<u8>,
}

/// Frame one record: `len | crc | (seq | header_len | header | body)`.
pub fn encode_record(seq: u64, header: &Json, body: &[u8]) -> Vec<u8> {
    let h = header.to_string();
    let payload_len = 8 + 4 + h.len() + body.len();
    let mut out = Vec::with_capacity(8 + payload_len);
    out.extend_from_slice(&(payload_len as u32).to_le_bytes());
    out.extend_from_slice(&[0u8; 4]); // crc placeholder
    out.extend_from_slice(&seq.to_le_bytes());
    out.extend_from_slice(&(h.len() as u32).to_le_bytes());
    out.extend_from_slice(h.as_bytes());
    out.extend_from_slice(body);
    let crc = crc32(&out[8..]);
    out[4..8].copy_from_slice(&crc.to_le_bytes());
    out
}

/// Result of scanning a byte run for records: every complete CRC-valid
/// record with its byte range, the length of the clean prefix, and why
/// the scan stopped early (`None` = the whole run was clean).
pub struct Scan {
    pub records: Vec<(WalRecord, Range<usize>)>,
    pub clean_len: usize,
    pub torn: Option<String>,
}

/// Parse records until the end of `buf` or the first torn/corrupt one.
/// Used by recovery (truncate the tail at `clean_len`), by the follower
/// (validate a fetched batch), and by `fetch` itself.
pub fn scan_records(buf: &[u8]) -> Scan {
    let mut records = Vec::new();
    let mut at = 0usize;
    let torn = loop {
        if at == buf.len() {
            break None;
        }
        if buf.len() - at < 8 {
            break Some(format!("truncated record prefix at byte {at}"));
        }
        let len = u32::from_le_bytes(buf[at..at + 4].try_into().unwrap()) as usize;
        let crc = u32::from_le_bytes(buf[at + 4..at + 8].try_into().unwrap());
        if len < 12 || len > MAX_RECORD_BYTES {
            break Some(format!("implausible record length {len} at byte {at}"));
        }
        let Some(end) = at.checked_add(8 + len).filter(|&e| e <= buf.len()) else {
            break Some(format!("record at byte {at} extends past the end"));
        };
        let payload = &buf[at + 8..end];
        if crc32(payload) != crc {
            break Some(format!("crc mismatch at byte {at}"));
        }
        let seq = u64::from_le_bytes(payload[..8].try_into().unwrap());
        let hlen = u32::from_le_bytes(payload[8..12].try_into().unwrap()) as usize;
        if hlen > len - 12 {
            break Some(format!("record at byte {at}: header overruns payload"));
        }
        let header = match std::str::from_utf8(&payload[12..12 + hlen])
            .ok()
            .and_then(|s| Json::parse(s).ok())
        {
            Some(h) => h,
            None => break Some(format!("record at byte {at}: unparseable header")),
        };
        let body = payload[12 + hlen..].to_vec();
        records.push((WalRecord { seq, header, body }, at..end));
        at = end;
    };
    Scan { records, clean_len: at, torn }
}

fn seg_header_bytes(epoch: u64, first_seq: u64) -> [u8; SEG_HEADER_LEN] {
    let mut h = [0u8; SEG_HEADER_LEN];
    h[..8].copy_from_slice(SEG_MAGIC);
    h[8] = SEG_VERSION;
    h[9..17].copy_from_slice(&epoch.to_le_bytes());
    h[17..25].copy_from_slice(&first_seq.to_le_bytes());
    h
}

fn parse_seg_header(buf: &[u8]) -> Result<(u64, u64)> {
    ensure!(buf.len() >= SEG_HEADER_LEN, "segment shorter than its header");
    ensure!(&buf[..8] == SEG_MAGIC, "bad segment magic");
    ensure!(
        buf[8] == SEG_VERSION,
        "segment version {} unsupported (this build reads {SEG_VERSION})",
        buf[8]
    );
    let epoch = u64::from_le_bytes(buf[9..17].try_into().unwrap());
    let first = u64::from_le_bytes(buf[17..25].try_into().unwrap());
    Ok((epoch, first))
}

fn seg_name(first_seq: u64) -> String {
    format!("wal-{first_seq:016x}.log")
}

/// Is this directory entry a checkpoint snapshot (either format)?
fn is_ckpt_file(name: &str) -> bool {
    name.starts_with("ckpt-") && (name.ends_with(".json") || name.ends_with(".bin"))
}

/// `(first_seq, path)` of every segment in `dir`, seq-ordered.
fn list_segments(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    for entry in fs::read_dir(dir).with_context(|| format!("listing {}", dir.display()))? {
        let entry = entry?;
        let name = entry.file_name();
        let Some(name) = name.to_str() else { continue };
        if let Some(hex) = name.strip_prefix("wal-").and_then(|s| s.strip_suffix(".log")) {
            if let Ok(first) = u64::from_str_radix(hex, 16) {
                out.push((first, entry.path()));
            }
        }
    }
    out.sort_by_key(|(first, _)| *first);
    Ok(out)
}

/// Best-effort directory fsync so freshly created/renamed names survive
/// a crash (POSIX: the dir entry is separate from the file's data).
fn sync_dir(dir: &Path) {
    if let Ok(d) = File::open(dir) {
        let _ = d.sync_all();
    }
}

// ── the log itself ───────────────────────────────────────────────────

struct WalInner {
    file: File,
    seg_path: PathBuf,
    seg_first: u64,
    /// Records in the active segment (0 ⇒ rotation can reuse the file).
    seg_records: u64,
    next_seq: u64,
    epoch: u64,
    last_sync: Instant,
    dirty: bool,
}

/// Append-only, CRC-framed, segmented op log. Appends serialise on one
/// internal mutex which is always acquired *last* (callers may hold
/// registry or session locks; the log never takes those), so log order
/// is exactly "order the effects became visible".
pub struct Wal {
    dir: PathBuf,
    policy: FsyncPolicy,
    checkpoint_bytes: u64,
    /// Format checkpoint snapshots are written in (reads always sniff).
    snapshot_format: SnapshotFormat,
    inner: Mutex<WalInner>,
    // lock-free mirrors for readers (sync-info, metrics, fetch)
    next_seq_m: AtomicU64,
    epoch_m: AtomicU64,
    bytes_since_ckpt: AtomicU64,
    checkpointing: AtomicBool,
}

/// One `fetch` response: the raw on-disk bytes of records
/// `[from, next)`, or `reset` when `from` predates the oldest retained
/// segment (the follower must re-bootstrap from snapshots).
pub struct Fetch {
    pub reset: bool,
    pub from: u64,
    pub next: u64,
    pub epoch: u64,
    pub count: u64,
    pub bytes: Vec<u8>,
}

impl Wal {
    #[allow(clippy::too_many_arguments)]
    fn open_inner(
        dir: PathBuf,
        policy: FsyncPolicy,
        checkpoint_bytes: u64,
        snapshot_format: SnapshotFormat,
        seg_path: PathBuf,
        seg_first: u64,
        seg_records: u64,
        next_seq: u64,
        epoch: u64,
    ) -> Result<Wal> {
        let file = OpenOptions::new()
            .append(true)
            .open(&seg_path)
            .with_context(|| format!("opening segment {}", seg_path.display()))?;
        Ok(Wal {
            dir,
            policy,
            checkpoint_bytes: checkpoint_bytes.max(1),
            snapshot_format,
            inner: Mutex::new(WalInner {
                file,
                seg_path,
                seg_first,
                seg_records,
                next_seq,
                epoch,
                last_sync: Instant::now(),
                dirty: false,
            }),
            next_seq_m: AtomicU64::new(next_seq),
            epoch_m: AtomicU64::new(epoch),
            bytes_since_ckpt: AtomicU64::new(0),
            checkpointing: AtomicBool::new(false),
        })
    }

    /// Create a fresh segment file (header written + synced) and return
    /// its path. Overwrites an existing file of the same name — callers
    /// only do that when reusing an empty segment for an epoch change.
    fn create_segment(dir: &Path, epoch: u64, first_seq: u64) -> Result<PathBuf> {
        let path = dir.join(seg_name(first_seq));
        let mut f = File::create(&path)
            .with_context(|| format!("creating segment {}", path.display()))?;
        f.write_all(&seg_header_bytes(epoch, first_seq))?;
        f.sync_all()
            .with_context(|| format!("syncing segment {}", path.display()))?;
        sync_dir(dir);
        Ok(path)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Format this log writes its checkpoint snapshots in (reads always
    /// sniff, so a directory may legitimately mix formats across a
    /// reconfiguration).
    pub fn snapshot_format(&self) -> SnapshotFormat {
        self.snapshot_format
    }

    /// Sequence number the next append will get.
    pub fn next_seq(&self) -> u64 {
        self.next_seq_m.load(Ordering::SeqCst)
    }

    /// Current epoch (bumped by promotion; the failover fence).
    pub fn epoch(&self) -> u64 {
        self.epoch_m.load(Ordering::SeqCst)
    }

    /// First seq still present in the log (records below it live only
    /// in checkpoint snapshots).
    pub fn oldest_retained(&self) -> Result<u64> {
        let segs = list_segments(&self.dir)?;
        Ok(segs.first().map(|(f, _)| *f).unwrap_or(self.next_seq()))
    }

    fn write_locked(&self, inner: &mut WalInner, bytes: &[u8]) -> Result<()> {
        inner
            .file
            .write_all(bytes)
            .with_context(|| format!("appending to {}", inner.seg_path.display()))?;
        inner.dirty = true;
        self.bytes_since_ckpt.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        let reg = obs::registry();
        reg.counter("nmbkm_wal_bytes_total", &[]).add(bytes.len() as u64);
        self.sync_locked(inner, false)?;
        Ok(())
    }

    fn sync_locked(&self, inner: &mut WalInner, force: bool) -> Result<()> {
        let due = match self.policy {
            FsyncPolicy::Always => true,
            FsyncPolicy::Interval(d) => inner.last_sync.elapsed() >= d,
            FsyncPolicy::Never => false,
        };
        if inner.dirty && (due || force) {
            inner.file.sync_data().context("fsync wal segment")?;
            inner.last_sync = Instant::now();
            inner.dirty = false;
            obs::registry().counter("nmbkm_wal_fsyncs_total", &[]).inc();
        }
        Ok(())
    }

    /// Append one op record; returns its sequence number. Durable per
    /// the fsync policy once this returns.
    pub fn append(&self, header: &Json, body: &[u8]) -> Result<u64> {
        let mut inner = self.inner.lock().unwrap();
        let seq = inner.next_seq;
        let rec = encode_record(seq, header, body);
        self.write_locked(&mut inner, &rec)?;
        inner.next_seq += 1;
        inner.seg_records += 1;
        self.next_seq_m.store(inner.next_seq, Ordering::SeqCst);
        obs::registry().counter("nmbkm_wal_appends_total", &[]).inc();
        Ok(seq)
    }

    /// Append a batch of already-framed records verbatim (the follower
    /// mirrors the primary's log bytes). Validates CRCs and seq
    /// contiguity, enforces the epoch fence, and adopts a newer remote
    /// epoch by rotating. Returns the last appended seq.
    pub fn append_raw(&self, bytes: &[u8], remote_epoch: u64) -> Result<u64> {
        let scan = scan_records(bytes);
        if let Some(t) = scan.torn {
            bail!("raw batch invalid: {t}");
        }
        ensure!(!scan.records.is_empty(), "raw batch holds no records");
        let mut inner = self.inner.lock().unwrap();
        ensure!(
            remote_epoch >= inner.epoch,
            "stale primary: batch epoch {} < local epoch {} (this node was promoted)",
            remote_epoch,
            inner.epoch
        );
        let first = scan.records[0].0.seq;
        ensure!(
            first == inner.next_seq,
            "raw batch starts at seq {first}, expected {}",
            inner.next_seq
        );
        for (i, (r, _)) in scan.records.iter().enumerate() {
            ensure!(r.seq == first + i as u64, "raw batch seqs not contiguous");
        }
        if remote_epoch > inner.epoch {
            self.rotate_locked(&mut inner, remote_epoch)?;
        }
        self.write_locked(&mut inner, bytes)?;
        inner.next_seq = first + scan.records.len() as u64;
        inner.seg_records += scan.records.len() as u64;
        self.next_seq_m.store(inner.next_seq, Ordering::SeqCst);
        obs::registry()
            .counter("nmbkm_wal_appends_total", &[])
            .add(scan.records.len() as u64);
        Ok(inner.next_seq - 1)
    }

    /// Flush and fsync regardless of policy (drain / checkpoint path).
    pub fn sync(&self) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        self.sync_locked(&mut inner, true)
    }

    fn rotate_locked(&self, inner: &mut WalInner, new_epoch: u64) -> Result<()> {
        if inner.seg_records == 0 && inner.seg_first == inner.next_seq {
            if new_epoch == inner.epoch {
                return Ok(()); // empty segment, nothing to rotate
            }
            // reuse the empty segment's name with the new epoch
            fs::remove_file(&inner.seg_path).ok();
        } else {
            self.sync_locked(inner, true)?;
        }
        let path = Self::create_segment(&self.dir, new_epoch, inner.next_seq)?;
        inner.file = OpenOptions::new().append(true).open(&path)?;
        inner.seg_path = path;
        inner.seg_first = inner.next_seq;
        inner.seg_records = 0;
        inner.epoch = new_epoch;
        inner.dirty = false;
        self.epoch_m.store(new_epoch, Ordering::SeqCst);
        Ok(())
    }

    /// Start a fresh segment at the current seq (checkpoints rotate so
    /// older segments become deletable).
    pub fn rotate(&self) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        let epoch = inner.epoch;
        self.rotate_locked(&mut inner, epoch)
    }

    /// Adopt `epoch` if it is newer than ours (rotating into a segment
    /// stamped with it). Rejects going backwards.
    pub fn adopt_epoch(&self, epoch: u64) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        ensure!(
            epoch >= inner.epoch,
            "refusing to lower epoch {} to {epoch}",
            inner.epoch
        );
        if epoch > inner.epoch {
            self.rotate_locked(&mut inner, epoch)?;
        }
        Ok(())
    }

    /// Promotion: bump the epoch by one. Every record this node logs
    /// from here on carries the new epoch, and [`append_raw`] (and the
    /// follower's apply path) rejects batches from the old one.
    pub fn bump_epoch(&self) -> Result<u64> {
        let mut inner = self.inner.lock().unwrap();
        let epoch = inner.epoch + 1;
        self.rotate_locked(&mut inner, epoch)?;
        Ok(epoch)
    }

    /// Wipe the log and restart at `next_seq`/`epoch` — the follower's
    /// bootstrap path (its history is replaced by shipped snapshots).
    pub fn reset_to(&self, next_seq: u64, epoch: u64) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        for (_, path) in list_segments(&self.dir)? {
            fs::remove_file(&path).ok();
        }
        fs::remove_file(self.dir.join(MANIFEST)).ok();
        for entry in fs::read_dir(&self.dir)?.flatten() {
            if let Some(name) = entry.file_name().to_str() {
                if is_ckpt_file(name) {
                    fs::remove_file(entry.path()).ok();
                }
            }
        }
        let path = Self::create_segment(&self.dir, epoch, next_seq)?;
        inner.file = OpenOptions::new().append(true).open(&path)?;
        inner.seg_path = path;
        inner.seg_first = next_seq;
        inner.seg_records = 0;
        inner.next_seq = next_seq;
        inner.epoch = epoch;
        inner.dirty = false;
        self.next_seq_m.store(next_seq, Ordering::SeqCst);
        self.epoch_m.store(epoch, Ordering::SeqCst);
        self.bytes_since_ckpt.store(0, Ordering::Relaxed);
        Ok(())
    }

    /// Raw record bytes from `from` (capped near `max_bytes`, always at
    /// least one record when any exists). Lock-free against appenders: a
    /// record half-written while we read simply ends the scan and is
    /// picked up whole by the next poll.
    pub fn fetch(&self, from: u64, max_bytes: usize) -> Result<Fetch> {
        let epoch = self.epoch();
        let next_live = self.next_seq();
        let segs = list_segments(&self.dir)?;
        let oldest = segs.first().map(|(f, _)| *f).unwrap_or(next_live);
        if from < oldest {
            return Ok(Fetch { reset: true, from, next: from, epoch, count: 0, bytes: Vec::new() });
        }
        let mut out = Vec::new();
        let mut count = 0u64;
        let mut next = from;
        'segs: for (first, path) in &segs {
            // skip segments entirely below the cursor
            if segs.iter().any(|(f2, _)| f2 > first && *f2 <= from) {
                continue;
            }
            let buf = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
            if buf.len() < SEG_HEADER_LEN {
                continue; // freshly rotated, header mid-write
            }
            parse_seg_header(&buf)?;
            let scan = scan_records(&buf[SEG_HEADER_LEN..]);
            for (rec, range) in &scan.records {
                if rec.seq < from {
                    continue;
                }
                if rec.seq != next {
                    break 'segs; // gap (rotation race) — serve what we have
                }
                let raw = &buf[SEG_HEADER_LEN + range.start..SEG_HEADER_LEN + range.end];
                if !out.is_empty() && out.len() + raw.len() > max_bytes {
                    break 'segs;
                }
                out.extend_from_slice(raw);
                count += 1;
                next = rec.seq + 1;
            }
        }
        Ok(Fetch { reset: false, from, next, epoch, count, bytes: out })
    }

    /// Bytes appended since the last completed checkpoint.
    pub fn bytes_since_checkpoint(&self) -> u64 {
        self.bytes_since_ckpt.load(Ordering::Relaxed)
    }

    /// Checkpoint iff the log has outgrown the configured threshold.
    pub fn maybe_checkpoint(&self, registry: &ModelRegistry) -> Result<bool> {
        if self.bytes_since_checkpoint() < self.checkpoint_bytes {
            return Ok(false);
        }
        self.checkpoint(registry)
    }

    /// Snapshot every model, write the manifest, drop old segments.
    /// Returns false when skipped (another thread checkpointing, or a
    /// model that cannot be snapshotted yet — its history stays in the
    /// log). Runs with no locks held on entry; takes each session lock
    /// briefly while streaming that model's snapshot.
    pub fn checkpoint(&self, registry: &ModelRegistry) -> Result<bool> {
        if self.checkpointing.swap(true, Ordering::SeqCst) {
            return Ok(false);
        }
        let out = self.checkpoint_inner(registry);
        self.checkpointing.store(false, Ordering::SeqCst);
        out
    }

    fn checkpoint_inner(&self, registry: &ModelRegistry) -> Result<bool> {
        let checkpointable = |e: &crate::serve::registry::ModelEntry| {
            e.with_session(|s| {
                Ok(s.initialised() && matches!(s.cfg().algo, Algo::GbRho | Algo::TbRho))
            })
            .unwrap_or(false)
        };
        // cheap precheck before rotating (a skipped checkpoint should
        // not litter segments)
        if registry.entries().iter().any(|e| !checkpointable(e)) {
            return Ok(false);
        }
        self.rotate()?;
        // entries are re-listed *after* the rotation point: any model
        // created or dropped from here on has its record in the new
        // segment, which survives the truncation below
        let entries = registry.entries();
        let mut models = Vec::new();
        for e in &entries {
            if !checkpointable(e) {
                return Ok(false); // created mid-checkpoint; retry later
            }
            let file = format!("ckpt-{}.{}", e.name(), self.snapshot_format.ext());
            let path = self.dir.join(&file);
            // the seq is read under the same session lock that guards
            // the snapshot, so "state in the file" and "ops it covers"
            // cannot be torn apart by a concurrent ingest
            let seq = e.with_session(|s| {
                let seq = e.last_seq();
                s.save_snapshot_as(&path, true, self.snapshot_format)?;
                Ok(seq)
            })?;
            if let Ok(f) = File::open(&path) {
                let _ = f.sync_all();
            }
            models.push((e.name().to_string(), file, seq));
        }
        // models evicted from memory live only in their checkpoint
        // file: keep listing them so the GC below and the segment
        // truncation never orphan the one copy a reload needs
        for (name, file, seq) in registry.evicted_for_checkpoint() {
            models.push((name, file, seq));
        }
        let manifest = json::obj(vec![
            ("version", json::num(1.0)),
            ("epoch", u64_json(self.epoch())),
            (
                "models",
                Json::Arr(
                    models
                        .iter()
                        .map(|(name, file, seq)| {
                            json::obj(vec![
                                ("name", json::s(name)),
                                ("file", json::s(file)),
                                ("seq", u64_json(*seq)),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        let tmp = self.dir.join("manifest.json.tmp");
        {
            let mut f = File::create(&tmp)?;
            f.write_all(manifest.to_string().as_bytes())?;
            f.sync_all()?;
        }
        fs::rename(&tmp, self.dir.join(MANIFEST))?;
        sync_dir(&self.dir);
        // older segments are fully covered by the snapshots now
        let active_first = self.inner.lock().unwrap().seg_first;
        for (first, path) in list_segments(&self.dir)? {
            if first < active_first {
                fs::remove_file(&path).ok();
            }
        }
        // snapshots of since-dropped models are garbage, and so is the
        // other-format twin of a model checkpointed under a new
        // --snapshot-format; collect both
        let live: BTreeSet<String> = models.iter().map(|(_, f, _)| f.clone()).collect();
        for entry in fs::read_dir(&self.dir)?.flatten() {
            if let Some(name) = entry.file_name().to_str() {
                if is_ckpt_file(name) && !live.contains(name) {
                    fs::remove_file(entry.path()).ok();
                }
            }
        }
        self.bytes_since_ckpt.store(0, Ordering::Relaxed);
        obs::registry().counter("nmbkm_wal_checkpoints_total", &[]).inc();
        Ok(true)
    }

    /// Graceful shutdown: flush + fsync whatever is buffered, then take
    /// a final checkpoint so the next start resumes from snapshots
    /// without replay. Checkpoint failures are non-fatal — the synced
    /// log alone already recovers everything.
    pub fn drain(&self, registry: &ModelRegistry) -> Result<()> {
        self.sync()?;
        if let Err(e) = self.checkpoint(registry) {
            eprintln!("[nmbkm::wal] final checkpoint failed (log retained): {e:#}");
        }
        Ok(())
    }
}

// ── replay ───────────────────────────────────────────────────────────

/// What applying a record did: `Skipped` covers records already folded
/// into a checkpoint snapshot and ops whose effects are unobservable
/// (e.g. an ingest racing a drop that won — the model is gone either
/// way).
#[derive(Debug, PartialEq)]
pub enum Applied {
    Applied,
    Skipped,
}

/// Apply one log record to the registry, **without** re-logging it.
/// Idempotent against checkpoints via per-model `last_seq`: a record at
/// or below the model's high-water mark is skipped. Deterministic:
/// `rounds` in the header is the count the primary *actually ran*, and
/// replay runs exactly those rounds with an infinite time budget.
pub fn apply_record(registry: &ModelRegistry, rec: &WalRecord) -> Result<Applied> {
    let h = &rec.header;
    let op = h
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("record {} has no op", rec.seq))?;
    let model = h
        .get("model")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("record {} ({op}) has no model", rec.seq))?;
    let entry = registry.resolve(Some(model)).ok();
    if let Some(e) = &entry {
        if e.last_seq() >= rec.seq {
            return Ok(Applied::Skipped); // already in a checkpoint
        }
    }
    match op {
        "create" => {
            ensure!(
                entry.is_none(),
                "record {}: create of existing model '{model}'",
                rec.seq
            );
            let dim = h
                .get("dim")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("record {}: create without dim", rec.seq))?;
            let cfgv = h
                .get("config")
                .ok_or_else(|| anyhow!("record {}: create without config", rec.seq))?;
            let cfg = RunConfig::from_json(cfgv)
                .map_err(|e| anyhow!("record {}: bad config: {e}", rec.seq))?;
            let mut session = OnlineSession::new(cfg, dim)?;
            session.set_snapshot_dir(registry.snapshot_dir());
            let e = registry.insert(model, session)?;
            e.set_last_seq(rec.seq);
        }
        "ingest" | "step" => {
            let Some(e) = entry else {
                // the model was dropped later in the log: its pending
                // mutations are unobservable, exactly as on the primary
                return Ok(Applied::Skipped);
            };
            let rounds = h
                .get("rounds")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow!("record {}: {op} without rounds", rec.seq))?;
            // step is called even for rounds == 0: the live request
            // path always calls it, and its unconditional try_init is a
            // state transition replay must mirror at the same position
            if op == "ingest" {
                let rows = wire::decode_rows(&rec.body)
                    .map_err(|er| anyhow!("record {}: bad ingest body: {er:#}", rec.seq))?;
                e.with_session_mut(|s| {
                    s.ingest_wire(&rows)?;
                    s.step(rounds, f64::INFINITY)?;
                    Ok(())
                })?;
            } else {
                e.with_session_mut(|s| {
                    s.step(rounds, f64::INFINITY)?;
                    Ok(())
                })?;
            }
            e.set_last_seq(rec.seq);
        }
        "drop" => {
            if entry.is_none() {
                return Ok(Applied::Skipped); // an earlier instance, already gone
            }
            registry.drop_model_unlogged(model)?;
        }
        other => bail!("record {}: unknown op '{other}'", rec.seq),
    }
    Ok(Applied::Applied)
}

// ── recovery ─────────────────────────────────────────────────────────

/// Outcome of [`recover`]: the opened log plus what it took to rebuild
/// the registry.
pub struct Recovery {
    pub wal: std::sync::Arc<Wal>,
    pub resumed_models: usize,
    pub replayed: u64,
    pub skipped: u64,
    pub truncated_bytes: u64,
}

/// Open (or initialise) the WAL directory and rebuild the registry:
/// resume checkpointed models from the manifest, scan the segments,
/// truncate a torn tail record in the last segment, replay the rest in
/// seq order. The returned log continues appending where the old
/// process stopped. Call [`ModelRegistry::attach_wal`] *after* this —
/// replay must never re-log.
pub fn recover(
    dir: &Path,
    policy: FsyncPolicy,
    checkpoint_bytes: u64,
    registry: &ModelRegistry,
) -> Result<Recovery> {
    recover_as(dir, policy, checkpoint_bytes, SnapshotFormat::Json, registry)
}

/// [`recover`] with an explicit checkpoint [`SnapshotFormat`]. The
/// format only affects snapshots this log will *write*; existing
/// checkpoints of either format are loaded transparently.
pub fn recover_as(
    dir: &Path,
    policy: FsyncPolicy,
    checkpoint_bytes: u64,
    snapshot_format: SnapshotFormat,
    registry: &ModelRegistry,
) -> Result<Recovery> {
    fs::create_dir_all(dir).with_context(|| format!("creating wal dir {}", dir.display()))?;
    let mut epoch = 1u64;
    let mut next_seq = 1u64;
    let mut resumed = 0usize;

    // 1. checkpointed models from the manifest
    let manifest_path = dir.join(MANIFEST);
    if manifest_path.exists() {
        let text = fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {}", manifest_path.display()))?;
        let v = Json::parse(&text).map_err(|e| anyhow!("manifest: {e}"))?;
        ensure!(
            v.get("version").and_then(Json::as_usize) == Some(1),
            "manifest version unsupported"
        );
        epoch = epoch.max(u64_field(&v, "epoch")?);
        let models = v
            .get("models")
            .and_then(Json::as_arr)
            .ok_or_else(|| anyhow!("manifest missing models"))?;
        for m in models {
            let name = m
                .get("name")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("manifest model without name"))?;
            let file = m
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("manifest model without file"))?;
            let seq = u64_field(m, "seq")?;
            let snap = Snapshot::load(&dir.join(file))
                .with_context(|| format!("checkpoint snapshot for '{name}'"))?;
            let mut session = OnlineSession::resume(snap)
                .map_err(|e| anyhow!("resuming checkpoint '{name}': {e:#}"))?;
            session.set_snapshot_dir(registry.snapshot_dir());
            if registry.resolve(Some(name)).is_ok() {
                // a CLI-preloaded model of the same name: the checkpoint
                // is strictly newer (it descends from logged ops)
                eprintln!("[nmbkm::wal] checkpoint supersedes preloaded model '{name}'");
                registry.drop_model_unlogged(name)?;
            }
            let entry = registry.insert(name, session)?;
            entry.set_last_seq(seq);
            next_seq = next_seq.max(seq + 1);
            resumed += 1;
        }
    }

    // 2. scan segments in seq order, truncating a torn tail
    let mut segs = list_segments(dir)?;
    let mut replayed = 0u64;
    let mut skipped = 0u64;
    let mut truncated = 0u64;
    let mut last_good: Option<(PathBuf, u64, u64)> = None; // path, first, records
    let mut drop_last = false;
    for (i, (first, path)) in segs.iter().enumerate() {
        let is_last = i + 1 == segs.len();
        let buf = fs::read(path).with_context(|| format!("reading {}", path.display()))?;
        let header = parse_seg_header(&buf);
        let (seg_epoch, seg_first) = match header {
            Ok(h) => h,
            Err(e) if is_last => {
                // the final rotation died mid-header: no record ever
                // made it in, so the file carries nothing
                eprintln!(
                    "[nmbkm::wal] dropping torn segment {}: {e:#}",
                    path.display()
                );
                fs::remove_file(path).ok();
                truncated += buf.len() as u64;
                drop_last = true;
                break;
            }
            Err(e) => return Err(e.context(format!("segment {}", path.display()))),
        };
        ensure!(
            seg_first == *first,
            "segment {} header first_seq {seg_first} != filename",
            path.display()
        );
        epoch = epoch.max(seg_epoch);
        let scan = scan_records(&buf[SEG_HEADER_LEN..]);
        if let Some(reason) = &scan.torn {
            if is_last {
                let keep = SEG_HEADER_LEN + scan.clean_len;
                truncated += (buf.len() - keep) as u64;
                eprintln!(
                    "[nmbkm::wal] truncating torn tail of {} at byte {keep}: {reason}",
                    path.display()
                );
                let f = OpenOptions::new().write(true).open(path)?;
                f.set_len(keep as u64)?;
                f.sync_all()?;
            } else {
                bail!(
                    "interior segment {} is corrupt ({reason}) — refusing to \
                     skip acknowledged records; restore from a replica or \
                     delete the wal dir to start fresh",
                    path.display()
                );
            }
        }
        let mut expect = *first;
        for (rec, _) in &scan.records {
            ensure!(
                rec.seq == expect,
                "segment {}: record seq {} != expected {expect}",
                path.display(),
                rec.seq
            );
            expect += 1;
            match apply_record(registry, rec)
                .with_context(|| format!("replaying record {}", rec.seq))?
            {
                Applied::Applied => replayed += 1,
                Applied::Skipped => skipped += 1,
            }
            next_seq = next_seq.max(rec.seq + 1);
        }
        last_good = Some((path.clone(), *first, scan.records.len() as u64));
    }
    if drop_last {
        segs.pop();
    }

    // 3. open the active segment (reuse the truncated tail segment, or
    // start a fresh one)
    let wal = match last_good {
        Some((path, first, records)) => Wal::open_inner(
            dir.to_path_buf(),
            policy,
            checkpoint_bytes,
            snapshot_format,
            path,
            first,
            records,
            next_seq,
            epoch,
        )?,
        None => {
            let path = Wal::create_segment(dir, epoch, next_seq)?;
            Wal::open_inner(
                dir.to_path_buf(),
                policy,
                checkpoint_bytes,
                snapshot_format,
                path,
                next_seq,
                0,
                next_seq,
                epoch,
            )?
        }
    };
    if replayed + skipped > 0 || resumed > 0 {
        eprintln!(
            "[nmbkm::wal] recovered {}: {resumed} model(s) from checkpoint, \
             {replayed} record(s) replayed, {skipped} skipped, {truncated} \
             torn byte(s) truncated (next seq {next_seq}, epoch {epoch})",
            dir.display()
        );
    }
    obs::registry()
        .counter("nmbkm_wal_recovered_records_total", &[])
        .add(replayed);
    Ok(Recovery {
        wal: std::sync::Arc::new(wal),
        resumed_models: resumed,
        replayed,
        skipped,
        truncated_bytes: truncated,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn crc32_matches_known_vectors() {
        // standard IEEE test vector
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn record_roundtrip_and_scan() {
        let h1 = json::obj(vec![("op", json::s("step")), ("rounds", json::num(2.0))]);
        let h2 = json::obj(vec![("op", json::s("drop"))]);
        let mut buf = encode_record(7, &h1, b"body-bytes");
        buf.extend_from_slice(&encode_record(8, &h2, b""));
        let scan = scan_records(&buf);
        assert!(scan.torn.is_none());
        assert_eq!(scan.clean_len, buf.len());
        assert_eq!(scan.records.len(), 2);
        assert_eq!(scan.records[0].0.seq, 7);
        assert_eq!(scan.records[0].0.body, b"body-bytes");
        assert_eq!(scan.records[0].0.header.to_string(), h1.to_string());
        assert_eq!(scan.records[1].0.seq, 8);
        // every truncation yields exactly the records that fit
        let first_len = scan.records[0].1.end;
        for cut in 0..buf.len() {
            let s = scan_records(&buf[..cut]);
            let want = if cut >= first_len { 1 } else { 0 };
            assert_eq!(s.records.len(), want, "cut {cut}");
            assert!(cut == buf.len() || s.torn.is_some() || cut == first_len);
        }
        // a flipped byte invalidates exactly the record it sits in
        let mut bad = buf.clone();
        bad[first_len + 12] ^= 0x40;
        let s = scan_records(&bad);
        assert_eq!(s.records.len(), 1);
        assert!(s.torn.is_some());
    }

    #[test]
    fn fsync_policy_parses() {
        assert_eq!(FsyncPolicy::parse("always").unwrap(), FsyncPolicy::Always);
        assert_eq!(FsyncPolicy::parse("never").unwrap(), FsyncPolicy::Never);
        assert_eq!(
            FsyncPolicy::parse("interval:250").unwrap(),
            FsyncPolicy::Interval(Duration::from_millis(250))
        );
        assert!(FsyncPolicy::parse("interval:").is_err());
        assert!(FsyncPolicy::parse("sometimes").is_err());
    }

    #[test]
    fn u64_hex_json_roundtrip() {
        let v = json::obj(vec![("seq", u64_json(u64::MAX))]);
        assert_eq!(u64_field(&v, "seq").unwrap(), u64::MAX);
        assert!(u64_field(&v, "missing").is_err());
    }

    #[test]
    fn segment_header_roundtrip() {
        let h = seg_header_bytes(3, 99);
        assert_eq!(parse_seg_header(&h).unwrap(), (3, 99));
        assert!(parse_seg_header(&h[..10]).is_err());
        let mut bad = h;
        bad[0] ^= 1;
        assert!(parse_seg_header(&bad).is_err());
    }
}
