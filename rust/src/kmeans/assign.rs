//! Assignment engines: who computes `argmin_j ‖x_i − c_j‖²`.
//!
//! * [`NativeEngine`] — pure-rust norms-trick loops, sharded across the
//!   coordinator pool. Works for dense and CSR data; the reference
//!   implementation every other engine is tested against. Dense
//!   selections run through the point-blocked SIMD micro-kernels
//!   ([`crate::linalg::simd::nearest_block`]): a strip of four centroid
//!   rows is re-used from cache across a block of points instead of
//!   re-streaming all k·d centroid floats for every single point.
//! * `runtime::XlaEngine` — dense tiles dispatched to the AOT-compiled
//!   Pallas/XLA artifacts over PJRT (Layer 1/2); implements the same
//!   [`AssignEngine`] trait and must agree with the native engine
//!   exactly (integration test `xla_parity`).
//!
//! Engines only produce `(label, d²)`; applying sufficient-statistics
//! updates stays with the algorithms (leader-side), keeping the engine
//! interface identical for mb, mb-f, gb-ρ and tb-ρ.

use crate::coordinator::shard::{chunk_ranges, split_outputs, Pool};
use crate::data::{Data, Storage};
use crate::kmeans::state::Centroids;
use crate::linalg::neighbours::{self, NeighbourCache, NeighbourIndex};
use crate::linalg::simd;
use crate::linalg::sparse::{self, TransposedCentroids};
use crate::obs;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

/// Kernel-level observability counters, interned once in the global
/// [`obs`] registry. Inner loops accumulate plain integers; each chunk
/// of sharded work flushes here exactly once, so the atomics never sit
/// on the per-point path.
struct KernelCounters {
    prune_points_gathered: Arc<obs::Counter>,
    prune_points_swept: Arc<obs::Counter>,
    prune_centroids_evaluated: Arc<obs::Counter>,
    prune_centroids_skipped: Arc<obs::Counter>,
}

fn kernel_counters() -> &'static KernelCounters {
    static K: OnceLock<KernelCounters> = OnceLock::new();
    K.get_or_init(|| {
        let reg = obs::registry();
        KernelCounters {
            prune_points_gathered: reg
                .counter("nmbkm_sparse_prune_points_gathered_total", &[]),
            prune_points_swept: reg
                .counter("nmbkm_sparse_prune_points_swept_total", &[]),
            prune_centroids_evaluated: reg
                .counter("nmbkm_sparse_prune_centroids_evaluated_total", &[]),
            prune_centroids_skipped: reg
                .counter("nmbkm_sparse_prune_centroids_skipped_total", &[]),
        }
    })
}

/// Flush one chunk's worth of prune tallies and the block-kernel
/// dispatch count for the tier that ran them.
fn flush_kernel_stats(stats: &sparse::BlockStats, blocks: u64) {
    if blocks == 0 {
        return;
    }
    simd::note_dispatch(simd::tier(), blocks);
    let kc = kernel_counters();
    kc.prune_points_gathered.add(stats.points_gathered);
    kc.prune_points_swept.add(stats.points_swept);
    kc.prune_centroids_evaluated.add(stats.centroids_evaluated);
    kc.prune_centroids_skipped.add(stats.centroids_skipped);
}

/// Which pruning scheme the nearest-centroid scan runs. The choice can
/// never change results — every strategy is bit-identical to the flat
/// scan on the faithful tiers — only how many centroid evaluations it
/// takes to get there, so it is safe to pick adaptively.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum Strategy {
    /// Per-chunk adaptive pick: exponion when the neighbour structure
    /// is live, otherwise norm-prune vs flat by the norm-spread
    /// precheck.
    #[default]
    Auto,
    /// Unpruned scan — cheapest per evaluation; what Auto picks on
    /// normalised corpora where norm bounds are provably inert.
    Flat,
    /// Norm-bound candidate pruning (the sparse row-blocked kernel).
    Norm,
    /// Exponion ball pruning over the sorted neighbour structure.
    Exponion,
}

/// Indexes into the per-strategy tallies / counters.
const S_FLAT: usize = 0;
const S_NORM: usize = 1;
const S_EXP: usize = 2;
const STRATEGY_NAMES: [&str; 3] = ["flat", "norm", "exponion"];

/// Per-engine tallies of points assigned and centroid evaluations per
/// *resolved* strategy. Tests assert prune effectiveness through these
/// (race-free: the global obs counters aggregate every engine in the
/// process, including concurrently running tests).
#[derive(Debug, Default)]
pub struct StrategyTally {
    points: [AtomicU64; 3],
    evals: [AtomicU64; 3],
}

impl StrategyTally {
    fn add(&self, s: usize, points: u64, evals: u64) {
        self.points[s].fetch_add(points, Ordering::Relaxed);
        self.evals[s].fetch_add(evals, Ordering::Relaxed);
    }

    /// `[(points, evaluations); 3]` in flat/norm/exponion order.
    pub fn snapshot(&self) -> [(u64, u64); 3] {
        [S_FLAT, S_NORM, S_EXP].map(|s| {
            (
                self.points[s].load(Ordering::Relaxed),
                self.evals[s].load(Ordering::Relaxed),
            )
        })
    }
}

/// Global per-strategy prune-rate counters
/// (`nmbkm_assign_points_total{strategy=…}` /
/// `nmbkm_assign_centroids_evaluated_total{strategy=…}`), interned once.
struct StrategyCounters {
    points: [Arc<obs::Counter>; 3],
    evals: [Arc<obs::Counter>; 3],
}

fn strategy_counters() -> &'static StrategyCounters {
    static S: OnceLock<StrategyCounters> = OnceLock::new();
    S.get_or_init(|| {
        let reg = obs::registry();
        StrategyCounters {
            points: STRATEGY_NAMES.map(|n| {
                reg.counter("nmbkm_assign_points_total", &[("strategy", n)])
            }),
            evals: STRATEGY_NAMES.map(|n| {
                reg.counter(
                    "nmbkm_assign_centroids_evaluated_total",
                    &[("strategy", n)],
                )
            }),
        }
    })
}

/// Flush one chunk's per-strategy tallies: the engine-local tally and
/// the global obs counters, once per chunk (never on the point path).
fn flush_strategy(tally: &StrategyTally, s: usize, points: u64, evals: u64) {
    if points == 0 {
        return;
    }
    tally.add(s, points, evals);
    let sc = strategy_counters();
    sc.points[s].add(points);
    sc.evals[s].add(evals);
}

/// A selection of datapoint indices to (re)assign.
#[derive(Clone, Copy, Debug)]
pub enum Sel<'a> {
    /// The contiguous prefix/window `[lo, hi)` — gb/tb active batches
    /// are prefixes of the per-seed shuffled data.
    Range(usize, usize),
    /// An explicit index list (mb random batches, tb dirty points).
    List(&'a [usize]),
}

impl Sel<'_> {
    pub fn len(&self) -> usize {
        match self {
            Sel::Range(lo, hi) => hi - lo,
            Sel::List(l) => l.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    #[inline]
    pub fn nth(&self, t: usize) -> usize {
        match self {
            Sel::Range(lo, _) => lo + t,
            Sel::List(l) => l[t],
        }
    }
}

/// An engine computes nearest centroids for a selection of points,
/// writing `out_lbl[t]`/`out_d2[t]` for the t-th selected point, and
/// returns the number of point-to-centroid distance computations.
pub trait AssignEngine {
    fn assign(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
        out_lbl: &mut [u32],
        out_d2: &mut [f32],
    ) -> u64;

    /// Full distance rows: `out_d2[t*k + j] = ‖x_{sel(t)} − c_j‖²`.
    /// Used by the tile-path tb-ρ to refresh a dirty point's complete
    /// bound row in one pass (the XLA engine serves this from the
    /// `distmat` artifact). Returns distance-computation count.
    fn dist_rows(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
        out_d2: &mut [f32],
    ) -> u64;

    /// Σ over the selection of min_j ‖x_i − c_j‖² (validation scoring).
    fn score(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
    ) -> (f64, u64) {
        let n = sel.len();
        let mut lbl = vec![0u32; n];
        let mut d2 = vec![0f32; n];
        let calcs = self.assign(data, sel, centroids, pool, &mut lbl, &mut d2);
        (d2.iter().map(|&x| x as f64).sum(), calcs)
    }

    fn name(&self) -> &'static str;

    /// `(hits, builds)` of the engine's transpose cache, when it has
    /// one (observability; scraped into the serve metrics registry).
    fn trans_cache_stats(&self) -> Option<(u64, u64)> {
        None
    }

    /// A shared handle on the engine's transpose cache, when it keeps
    /// one. Metric scrapes read its counters through this handle
    /// lock-free — without touching whatever lock guards the engine
    /// itself (a serving session's mutex may be held for seconds by a
    /// training step).
    fn trans_cache_handle(&self) -> Option<Arc<TransCache>> {
        None
    }

    /// A shareable transposed-centroid handle at this centroid
    /// revision, when the engine keeps one. The serve layer carries it
    /// into published model views so sparse predicts reuse the training
    /// session's O(k·d) transpose instead of rebuilding their own.
    fn trans_handle(
        &self,
        _centroids: &Centroids,
    ) -> Option<Arc<TransposedCentroids>> {
        None
    }

    /// [`AssignEngine::assign`] with an externally shared transposed
    /// block for sparse data. Published-model predicts pass the
    /// transpose frozen into their view, bypassing the engine's cache
    /// entirely — concurrent predicts racing across publishes can never
    /// evict each other into a rebuild. Engines without a sparse fast
    /// path ignore the handle.
    #[allow(clippy::too_many_arguments)]
    fn assign_with_trans(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
        _trans: Option<Arc<TransposedCentroids>>,
        out_lbl: &mut [u32],
        out_d2: &mut [f32],
    ) -> u64 {
        self.assign(data, sel, centroids, pool, out_lbl, out_d2)
    }

    /// `(hits, builds, syncs)` of the engine's exponion neighbour
    /// cache, when it keeps one (observability; scraped into the serve
    /// metrics registry next to the transpose-cache counters).
    fn neigh_cache_stats(&self) -> Option<(u64, u64, u64)> {
        None
    }

    /// A shared handle on the engine's neighbour cache, for lock-free
    /// metric scrapes (same rationale as
    /// [`AssignEngine::trans_cache_handle`]).
    fn neigh_cache_handle(&self) -> Option<Arc<NeighbourCache>> {
        None
    }

    /// A shareable exponion neighbour structure at this centroid
    /// revision, when the engine keeps one worth sharing. The serve
    /// layer freezes it into published model views so predicts reuse
    /// the training session's O(k²·d) build — zero rebuilds between
    /// publishes.
    fn neigh_handle(
        &self,
        _centroids: &Centroids,
    ) -> Option<Arc<NeighbourIndex>> {
        None
    }

    /// [`AssignEngine::assign_with_trans`] plus an externally shared
    /// exponion neighbour structure. Both handles are frozen by the
    /// publisher together with `centroids`; engines without pruned
    /// paths ignore what they can't use. Results are bit-identical
    /// whichever handles arrive.
    #[allow(clippy::too_many_arguments)]
    fn assign_with_handles(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
        trans: Option<Arc<TransposedCentroids>>,
        _neigh: Option<Arc<NeighbourIndex>>,
        out_lbl: &mut [u32],
        out_d2: &mut [f32],
    ) -> u64 {
        self.assign_with_trans(data, sel, centroids, pool, trans, out_lbl, out_d2)
    }
}

/// Pure-rust engine; the correctness reference. Each instance owns its
/// own [`TransCache`], so independent sessions (one engine per
/// [`crate::serve::OnlineSession`]) never evict each other's transposed
/// centroid block — the process-global single slot a previous revision
/// used was correct but thrashed as soon as two sparse models trained
/// concurrently.
#[derive(Clone, Debug, Default)]
pub struct NativeEngine {
    cache: Arc<TransCache>,
    neigh: Arc<NeighbourCache>,
    strategy: Strategy,
    tally: Arc<StrategyTally>,
}

impl NativeEngine {
    /// The engine's transpose cache (tests and cache-sharing callers).
    pub fn cache(&self) -> &TransCache {
        &self.cache
    }

    /// The engine's exponion neighbour cache.
    pub fn neigh_cache(&self) -> &NeighbourCache {
        &self.neigh
    }

    /// Per-strategy (points, evaluations) tallies for this engine.
    pub fn strategy_tally(&self) -> &StrategyTally {
        &self.tally
    }

    /// Pin the pruning strategy (benches and parity tests; serving
    /// leaves the default `Auto`).
    pub fn with_strategy(mut self, strategy: Strategy) -> Self {
        self.strategy = strategy;
        self
    }

    /// The sharded assignment core: fan the selection out over the pool
    /// with already-resolved (or absent) transpose/neighbour handles.
    #[allow(clippy::too_many_arguments)]
    fn assign_sharded(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
        trans: Option<&TransposedCentroids>,
        neigh: Option<&NeighbourIndex>,
        out_lbl: &mut [u32],
        out_d2: &mut [f32],
    ) -> u64 {
        let n = sel.len();
        assert_eq!(out_lbl.len(), n);
        assert_eq!(out_d2.len(), n);
        if n == 0 {
            return 0;
        }
        // chunk-invariant half of the adaptive precheck, hoisted out of
        // the sharded closures
        let flat_c = norm_spread_flat(&centroids.norms);
        let ranges = chunk_ranges(n, pool.threads, MIN_CHUNK);
        let views = split_outputs(&ranges, out_lbl, out_d2);
        // pair each view with its range and fan out over the pool
        let jobs: Vec<_> = ranges.into_iter().zip(views).collect();
        let k = centroids.k() as u64;
        let strategy = self.strategy;
        let tally = &self.tally;
        pool.run_jobs(jobs, |_, (r, (vl, vd))| {
            assign_serial(
                data, &sel, r, centroids, trans, neigh, strategy, flat_c,
                tally, vl, vd,
            );
        });
        n as u64 * k
    }
}

/// Auto only pays the O(k²·d) neighbour build beyond this k — under it
/// the flat/norm kernels win outright. Forced `Strategy::Exponion`
/// builds at any k ≥ 2.
pub const EXPONION_MIN_K: usize = 512;

/// Auto skips exponion for sparse data above this dimensionality: the
/// dense k×k build is O(k²·d) in the *full* vocab, which RCV1-scale
/// vocabularies (47k) would pay on every centroid rebuild.
pub const EXPONION_SPARSE_MAX_D: usize = 8192;

/// Footprint cap on the k×(k−1) neighbour structure.
pub(crate) const NEIGH_MAX_BYTES: usize = 256 << 20;

/// Norm-prune precheck: when centroid and point √norms each sit within
/// this relative spread, every norm lower bound collapses to (nearly)
/// the same value and pruning is provably inert — run the flat kernel.
const NORM_SPREAD_MIN: f64 = 0.05;

/// `true` when √norm spread is too narrow for norm bounds to prune.
fn spread_is_flat(lo: f32, hi: f32) -> bool {
    let (lo, hi) = ((lo.max(0.0) as f64).sqrt(), (hi.max(0.0) as f64).sqrt());
    hi <= 0.0 || (hi - lo) <= NORM_SPREAD_MIN * hi
}

/// Centroid half of the precheck (chunk-invariant).
fn norm_spread_flat(cnorms: &[f32]) -> bool {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for &n in cnorms {
        lo = lo.min(n);
        hi = hi.max(n);
    }
    spread_is_flat(lo, hi)
}

/// Point half of the precheck, over one chunk's selection.
fn chunk_points_flat(data: &Data, sel: &Sel, range: &std::ops::Range<usize>) -> bool {
    let mut lo = f32::INFINITY;
    let mut hi = f32::NEG_INFINITY;
    for t in range.clone() {
        let n = data.norms[sel.nth(t)];
        lo = lo.min(n);
        hi = hi.max(n);
    }
    spread_is_flat(lo, hi)
}

/// Resolve the neighbour structure for this call, or `None` when
/// exponion shouldn't run. A revision-matched structure already in the
/// cache is free at any size (probe never builds); Auto pays a build
/// only past the serving-scale gates, a forced `Strategy::Exponion`
/// always does.
fn neigh_for(
    cache: &NeighbourCache,
    data: &Data,
    centroids: &Centroids,
    n_points: usize,
    strategy: Strategy,
) -> Option<Arc<NeighbourIndex>> {
    let (k, d) = (centroids.k(), centroids.d());
    if k < 2 || neighbours::NeighbourRows::bytes_for(k) > NEIGH_MAX_BYTES {
        return None;
    }
    match strategy {
        Strategy::Exponion => Some(cache.get(centroids, simd::tier())),
        Strategy::Auto => {
            if let Some(ni) = cache.probe(centroids) {
                return Some(ni);
            }
            let build = k >= EXPONION_MIN_K
                && n_points >= 64
                && (!data.is_sparse() || d <= EXPONION_SPARSE_MAX_D);
            build.then(|| cache.get(centroids, simd::tier()))
        }
        Strategy::Flat | Strategy::Norm => None,
    }
}

/// Don't fan out to threads for selections smaller than this
/// (per-item work is one k-way nearest scan).
const MIN_CHUNK: usize = 256;

/// `dist_rows` fans out earlier: per-item work there is a full row of k
/// distances, so much smaller selections already amortise a chunk
/// hand-off. (A previous revision wrote `MIN_CHUNK.max(64)`, which
/// evaluates to 256 — a chunking no-op that serialised the tb-ρ tile
/// path's 100-point dirty batches.)
const DIST_ROWS_MIN_CHUNK: usize = 64;

impl AssignEngine for NativeEngine {
    fn assign(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
        out_lbl: &mut [u32],
        out_d2: &mut [f32],
    ) -> u64 {
        if sel.is_empty() {
            assert_eq!(out_lbl.len(), 0);
            assert_eq!(out_d2.len(), 0);
            return 0;
        }
        // sparse fast path: transposed centroids turn per-nnz gathers
        // into sequential k-length AXPYs (EXPERIMENTS.md §Perf, ~2x)
        let trans = transposed_for(&self.cache, data, centroids, sel.len());
        let neigh =
            neigh_for(&self.neigh, data, centroids, sel.len(), self.strategy);
        self.assign_sharded(
            data,
            sel,
            centroids,
            pool,
            trans.as_deref(),
            neigh.as_deref(),
            out_lbl,
            out_d2,
        )
    }

    fn assign_with_trans(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
        trans: Option<Arc<TransposedCentroids>>,
        out_lbl: &mut [u32],
        out_d2: &mut [f32],
    ) -> u64 {
        self.assign_with_handles(
            data, sel, centroids, pool, trans, None, out_lbl, out_d2,
        )
    }

    fn assign_with_handles(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
        trans: Option<Arc<TransposedCentroids>>,
        neigh: Option<Arc<NeighbourIndex>>,
        out_lbl: &mut [u32],
        out_d2: &mut [f32],
    ) -> u64 {
        if sel.is_empty() {
            return self.assign(data, sel, centroids, pool, out_lbl, out_d2);
        }
        // shared-handle fast path: the caller froze these together with
        // `centroids`, so no cache lookup happens at all — concurrent
        // callers holding different revisions can never force a rebuild
        // here. Recorded as hits for counter parity with the cached
        // paths.
        let usable_t = trans.filter(|tc| {
            data.is_sparse()
                && tc.k == centroids.k()
                && tc.d == centroids.d()
        });
        if usable_t.is_some() {
            self.cache.note_shared();
        }
        let usable_n = neigh.filter(|ni| {
            ni.rev == centroids.rev
                && ni.k() == centroids.k()
                && ni.d() == centroids.d()
        });
        if usable_n.is_some() {
            self.neigh.note_shared();
        }
        // handles the caller didn't bring resolve through this engine's
        // own caches — probe-only for the neighbour structure: a
        // predict engine must never pay an O(k²·d) build per query
        let t_local = if usable_t.is_none() {
            transposed_for(&self.cache, data, centroids, sel.len())
        } else {
            None
        };
        let n_local = if usable_n.is_none()
            && matches!(self.strategy, Strategy::Auto | Strategy::Exponion)
        {
            self.neigh.probe(centroids)
        } else {
            None
        };
        self.assign_sharded(
            data,
            sel,
            centroids,
            pool,
            usable_t.as_deref().or(t_local.as_deref()),
            usable_n.as_deref().or(n_local.as_deref()),
            out_lbl,
            out_d2,
        )
    }

    fn dist_rows(
        &self,
        data: &Data,
        sel: Sel,
        centroids: &Centroids,
        pool: &Pool,
        out_d2: &mut [f32],
    ) -> u64 {
        let n = sel.len();
        let k = centroids.k();
        assert_eq!(out_d2.len(), n * k);
        if n == 0 {
            return 0;
        }
        let ranges = chunk_ranges(n, pool.threads, DIST_ROWS_MIN_CHUNK);
        // split the row-major output at row boundaries
        let mut views = Vec::with_capacity(ranges.len());
        {
            let mut rest: &mut [f32] = out_d2;
            for r in &ranges {
                let (head, tail) = rest.split_at_mut(r.len() * k);
                views.push(head);
                rest = tail;
            }
        }
        let jobs: Vec<_> = ranges.into_iter().zip(views).collect();
        let trans = transposed_for(&self.cache, data, centroids, n);
        let trans = trans.as_deref();
        pool.run_jobs(jobs, |_, (r, out)| {
            dist_rows_serial(data, &sel, r, centroids, trans, out);
        });
        (n * k) as u64
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn trans_cache_stats(&self) -> Option<(u64, u64)> {
        Some((self.cache.hits(), self.cache.builds()))
    }

    fn trans_cache_handle(&self) -> Option<Arc<TransCache>> {
        Some(self.cache.clone())
    }

    fn trans_handle(
        &self,
        centroids: &Centroids,
    ) -> Option<Arc<TransposedCentroids>> {
        if centroids.k() < 8
            || TransposedCentroids::bytes_for(centroids.k(), centroids.d())
                > TRANS_MAX_BYTES
        {
            return None;
        }
        Some(self.cache.fetch(centroids))
    }

    fn neigh_cache_stats(&self) -> Option<(u64, u64, u64)> {
        Some(self.neigh.stats())
    }

    fn neigh_cache_handle(&self) -> Option<Arc<NeighbourCache>> {
        Some(self.neigh.clone())
    }

    fn neigh_handle(
        &self,
        centroids: &Centroids,
    ) -> Option<Arc<NeighbourIndex>> {
        let k = centroids.k();
        if k < 2
            || neighbours::NeighbourRows::bytes_for(k) > NEIGH_MAX_BYTES
            || !matches!(self.strategy, Strategy::Auto | Strategy::Exponion)
        {
            return None;
        }
        if let Some(ni) = self.neigh.probe(centroids) {
            return Some(ni);
        }
        // publishing is rare enough to amortise a build at serving
        // scale; below the Auto gate only a pinned-Exponion engine pays
        (self.strategy == Strategy::Exponion || k >= EXPONION_MIN_K)
            .then(|| self.neigh.get(centroids, simd::tier()))
    }
}

/// Per-engine transpose cache keyed on [`Centroids::rev`]: within a
/// round, `assign`, `dist_rows` and validation scoring all see the same
/// centroid revision, so the O(k·d) transpose is built once instead of
/// once per engine call. One cache per [`NativeEngine`] (hence per
/// session) keeps concurrently-training sparse models from evicting
/// each other. Hit/build counters are plain observability — they never
/// influence results.
#[derive(Debug, Default)]
pub struct TransCache {
    slot: Mutex<TransSlot>,
    hits: AtomicU64,
    builds: AtomicU64,
}

/// The cache slot plus a small free-list of retired blocks. A retired
/// block is one that was current until a publish (or another reader)
/// pinned it past its revision: it couldn't be rebuilt in place at the
/// time, but once the pinning reader drops — the next publish swapping
/// its view out — the allocation comes back here and the warm path is
/// allocation-free again.
#[derive(Debug, Default)]
struct TransSlot {
    cur: Option<(u64, Arc<TransposedCentroids>)>,
    retired: Vec<Arc<TransposedCentroids>>,
}

/// Retired blocks kept per cache. One slot covers the steady publish
/// cadence (one pinned view at a time); a few more absorb bursts of
/// overlapping readers without holding dead k·d blocks forever.
const RETIRED_MAX: usize = 4;

impl TransCache {
    /// Revision-matched transposes served without a rebuild.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// O(k·d) transpose fills (cache misses; in-place rebuilds count —
    /// they redo the fill, just not the allocation).
    pub fn builds(&self) -> u64 {
        self.builds.load(Ordering::Relaxed)
    }

    /// Revision-matched transpose already in the slot (counted as a
    /// hit), or `None`. This is the warm-path gate: a probe never
    /// triggers a build.
    pub fn probe(&self, centroids: &Centroids) -> Option<Arc<TransposedCentroids>> {
        let tc = cache_lookup(&self.slot.lock().unwrap().cur, centroids)?;
        self.hits.fetch_add(1, Ordering::Relaxed);
        Some(tc)
    }

    /// Fetch the transpose for this centroid revision, building (and
    /// caching) it on a miss. On a miss the stale entry's allocation —
    /// or a previously retired one whose pinning reader has since
    /// dropped — is reclaimed and rebuilt in place, so steady-state
    /// training *and* the publish cadence stop reallocating O(k·d)
    /// every centroid revision. Entries still pinned by a reader (a
    /// published model view holds its block until the next publish
    /// swaps it out) park on the retired list until they free up. The
    /// fill runs outside the slot lock so a large transpose never
    /// serialises concurrent readers of the slot.
    pub fn fetch(&self, centroids: &Centroids) -> Arc<TransposedCentroids> {
        if let Some(tc) = self.probe(centroids) {
            return tc;
        }
        let reclaimed = {
            let mut slot = self.slot.lock().unwrap();
            let TransSlot { cur, retired } = &mut *slot;
            if let Some((_, arc)) = cur.take() {
                retired.push(arc);
            }
            // oldest-first scan: earlier retirees are the most likely
            // to have been unpinned by now
            let mut got = None;
            let mut p = 0;
            while p < retired.len() {
                if Arc::strong_count(&retired[p]) == 1 {
                    match Arc::try_unwrap(retired.swap_remove(p)) {
                        Ok(t) => {
                            got = Some(t);
                            break;
                        }
                        // a reader cloned it between the count check
                        // and the unwrap; park it again
                        Err(arc) => retired.push(arc),
                    }
                }
                p += 1;
            }
            if retired.len() > RETIRED_MAX {
                let excess = retired.len() - RETIRED_MAX;
                retired.drain(..excess);
            }
            got
        };
        let tc = match reclaimed {
            Some(mut t) => {
                t.rebuild(&centroids.c);
                Arc::new(t)
            }
            None => Arc::new(TransposedCentroids::build(&centroids.c)),
        };
        self.builds.fetch_add(1, Ordering::Relaxed);
        self.slot.lock().unwrap().cur = Some((centroids.rev, tc.clone()));
        tc
    }

    /// Record a serve from an externally shared transpose
    /// ([`AssignEngine::assign_with_trans`]): counter parity with probe
    /// hits, no slot interaction.
    fn note_shared(&self) {
        self.hits.fetch_add(1, Ordering::Relaxed);
    }
}

/// Revision-matched cache hit, or `None`.
fn cache_lookup(
    slot: &Option<(u64, Arc<TransposedCentroids>)>,
    centroids: &Centroids,
) -> Option<Arc<TransposedCentroids>> {
    match slot {
        Some((rev, tc))
            if *rev == centroids.rev
                && tc.k == centroids.k()
                && tc.d == centroids.d() =>
        {
            Some(tc.clone())
        }
        _ => None,
    }
}

/// Footprint cap on cached transposes (bounds per-session memory).
const TRANS_MAX_BYTES: usize = 256 << 20;

/// Build (or fetch) the transposed centroid block when it pays: sparse
/// data, k large enough to amortise, selection big enough to amortise
/// the O(k·d) transpose, and a bounded memory footprint. A
/// revision-matched transpose already in the cache (built by an earlier
/// call at this revision) is free and
/// is used even for selections the build gates would reject — the
/// choice never changes results, because the AXPY lanes accumulate in
/// the same order as the gather path's `spdot`, bit for bit.
fn transposed_for(
    cache: &TransCache,
    data: &Data,
    centroids: &Centroids,
    n_points: usize,
) -> Option<Arc<TransposedCentroids>> {
    if !data.is_sparse() {
        return None;
    }
    if let Some(tc) = cache.probe(centroids) {
        return Some(tc);
    }
    if centroids.k() < 8
        || n_points < 64
        || TransposedCentroids::bytes_for(centroids.k(), centroids.d())
            > TRANS_MAX_BYTES
    {
        return None;
    }
    Some(cache.fetch(centroids))
}

#[allow(clippy::too_many_arguments)]
fn assign_serial(
    data: &Data,
    sel: &Sel,
    range: std::ops::Range<usize>,
    centroids: &Centroids,
    trans: Option<&TransposedCentroids>,
    neigh: Option<&NeighbourIndex>,
    strategy: Strategy,
    flat_centroids: bool,
    tally: &StrategyTally,
    out_lbl: &mut [u32],
    out_d2: &mut [f32],
) {
    if let Storage::Shard(_) = &data.storage {
        // Disk-backed rows: stage this chunk's rows (same values, same
        // norms, same order) into an owned in-RAM block and run the
        // identical kernels over it — bit-identical to the in-RAM
        // path, with temp memory bounded by the chunk size.
        let local = data.gather_rows(range.clone().map(|t| sel.nth(t)));
        let n = local.n();
        return assign_serial(
            &local,
            &Sel::Range(0, n),
            0..n,
            centroids,
            trans,
            neigh,
            strategy,
            flat_centroids,
            tally,
            out_lbl,
            out_d2,
        );
    }
    let use_exp =
        neigh.is_some() && matches!(strategy, Strategy::Auto | Strategy::Exponion);
    match (trans, &data.storage) {
        (Some(tc), Storage::Sparse(m)) if use_exp => {
            // exponion over the transpose: norm bounds seed the ball,
            // the sorted neighbour row cuts the walk — bit-identical to
            // the unpruned sweep
            let ni = neigh.unwrap();
            let k = tc.k;
            let mut lbs = vec![0f32; k];
            let mut points = 0u64;
            let mut evals = 0u64;
            for (slot, t) in range.clone().enumerate() {
                let i = sel.nth(t);
                let (idx, vals) = m.row(i);
                let (j, d2, ev) = neighbours::nearest_sparse_exponion(
                    tc,
                    idx,
                    vals,
                    data.norms[i],
                    &centroids.norms,
                    ni,
                    &mut lbs,
                );
                out_lbl[slot] = j;
                out_d2[slot] = d2;
                points += 1;
                evals += ev as u64;
            }
            if points > 0 {
                simd::note_dispatch(simd::tier(), points);
            }
            flush_strategy(tally, S_EXP, points, evals);
        }
        (Some(tc), Storage::Sparse(m)) => {
            // row-blocked: points go through the transpose in
            // SPARSE_BLOCK batches (phase-separated pruning/AXPY keeps
            // the shared d×k strips cache-resident) — bit-identical to
            // the per-point unpruned scan. The adaptive precheck drops
            // the norm-prune phase when bounds are provably inert
            // (normalised corpora), where it was pure overhead.
            let use_flat = match strategy {
                Strategy::Flat => true,
                Strategy::Norm => false,
                _ => flat_centroids && chunk_points_flat(data, sel, &range),
            };
            let k = tc.k;
            let mut scratch = vec![0f32; k];
            let mut lbs = vec![0f32; k];
            let mut rows: [(&[u32], &[f32]); sparse::SPARSE_BLOCK] =
                [(&[], &[]); sparse::SPARSE_BLOCK];
            let mut xns = [0f32; sparse::SPARSE_BLOCK];
            let mut stats = sparse::BlockStats::default();
            let mut blocks = 0u64;
            let mut t0 = range.start;
            while t0 < range.end {
                let p = sparse::SPARSE_BLOCK.min(range.end - t0);
                for o in 0..p {
                    let i = sel.nth(t0 + o);
                    rows[o] = m.row(i);
                    xns[o] = data.norms[i];
                }
                let base = t0 - range.start;
                if use_flat {
                    stats.merge(tc.nearest_block_flat(
                        &rows[..p],
                        &xns[..p],
                        &centroids.norms,
                        &mut scratch,
                        &mut out_lbl[base..base + p],
                        &mut out_d2[base..base + p],
                    ));
                } else {
                    stats.merge(tc.nearest_block(
                        &rows[..p],
                        &xns[..p],
                        &centroids.norms,
                        &mut lbs,
                        &mut scratch,
                        &mut out_lbl[base..base + p],
                        &mut out_d2[base..base + p],
                    ));
                }
                blocks += 1;
                t0 += p;
            }
            let points = (range.end - range.start) as u64;
            if use_flat {
                if blocks > 0 {
                    simd::note_dispatch(simd::tier(), blocks);
                }
                flush_strategy(tally, S_FLAT, points, stats.centroids_evaluated);
            } else {
                flush_kernel_stats(&stats, blocks);
                flush_strategy(tally, S_NORM, points, stats.centroids_evaluated);
            }
        }
        (_, Storage::Sparse(m)) => {
            for (slot, t) in range.clone().enumerate() {
                let i = sel.nth(t);
                let (idx, vals) = m.row(i);
                let (j, d2) = sparse::nearest_sparse(
                    idx,
                    vals,
                    data.norms[i],
                    &centroids.c,
                    &centroids.norms,
                );
                out_lbl[slot] = j;
                out_d2[slot] = d2;
            }
            let points = (range.end - range.start) as u64;
            flush_strategy(tally, S_FLAT, points, points * centroids.k() as u64);
        }
        (_, Storage::Dense(m)) if use_exp => {
            // exponion over dense rows: strided probes seed the ball,
            // the sorted neighbour row cuts the walk — bit-identical to
            // the flat scan
            let ni = neigh.unwrap();
            let tier = simd::tier();
            let mut points = 0u64;
            let mut evals = 0u64;
            for (slot, t) in range.clone().enumerate() {
                let i = sel.nth(t);
                let (j, d2, ev) = neighbours::nearest_dense_exponion(
                    tier,
                    m.row(i),
                    data.norms[i],
                    &centroids.c,
                    &centroids.norms,
                    ni,
                );
                out_lbl[slot] = j;
                out_d2[slot] = d2;
                points += 1;
                evals += ev as u64;
            }
            if points > 0 {
                simd::note_dispatch(tier, points);
            }
            flush_strategy(tally, S_EXP, points, evals);
        }
        (_, Storage::Dense(m)) => {
            // point-blocked: a 4-row centroid strip stays in cache
            // across POINT_BLOCK points (bit-identical to per-point)
            let tier = simd::tier();
            let mut blocks = 0u64;
            let mut rows: [&[f32]; simd::POINT_BLOCK] = [&[]; simd::POINT_BLOCK];
            let mut xns = [0f32; simd::POINT_BLOCK];
            let mut t0 = range.start;
            while t0 < range.end {
                let p = simd::POINT_BLOCK.min(range.end - t0);
                for o in 0..p {
                    let i = sel.nth(t0 + o);
                    rows[o] = m.row(i);
                    xns[o] = data.norms[i];
                }
                let base = t0 - range.start;
                simd::nearest_block_with(
                    tier,
                    &rows[..p],
                    &xns[..p],
                    &centroids.c,
                    &centroids.norms,
                    &mut out_lbl[base..base + p],
                    &mut out_d2[base..base + p],
                );
                blocks += 1;
                t0 += p;
            }
            simd::note_dispatch(tier, blocks);
            let points = (range.end - range.start) as u64;
            flush_strategy(tally, S_FLAT, points, points * centroids.k() as u64);
        }
        (_, Storage::Shard(_)) => unreachable!("shard chunks are staged above"),
    }
}

fn dist_rows_serial(
    data: &Data,
    sel: &Sel,
    range: std::ops::Range<usize>,
    centroids: &Centroids,
    trans: Option<&TransposedCentroids>,
    out: &mut [f32],
) {
    let k = centroids.k();
    if let Storage::Shard(_) = &data.storage {
        // Same staging trick as `assign_serial`: materialise the chunk
        // and recurse on the in-RAM kernels.
        let local = data.gather_rows(range.clone().map(|t| sel.nth(t)));
        let n = local.n();
        return dist_rows_serial(&local, &Sel::Range(0, n), 0..n, centroids, trans, out);
    }
    match (trans, &data.storage) {
        (Some(tc), Storage::Sparse(m)) => {
            for (slot, t) in range.clone().enumerate() {
                let i = sel.nth(t);
                let (idx, vals) = m.row(i);
                tc.dist_row(
                    idx,
                    vals,
                    data.norms[i],
                    &centroids.norms,
                    &mut out[slot * k..(slot + 1) * k],
                );
            }
        }
        (_, Storage::Sparse(m)) => {
            // no-transpose fallback: hoist the CSR row and its norm
            // once and run spdot per centroid, instead of re-deriving
            // both through `data.sq_dist_to` for every (i, j) pair
            for (slot, t) in range.clone().enumerate() {
                let i = sel.nth(t);
                let (idx, vals) = m.row(i);
                let xn = data.norms[i];
                let row = &mut out[slot * k..(slot + 1) * k];
                for j in 0..k {
                    row[j] = sparse::sq_dist_sparse(
                        idx,
                        vals,
                        xn,
                        centroids.c.row(j),
                        centroids.norms[j],
                    );
                }
            }
        }
        (_, Storage::Dense(m)) => {
            let tier = simd::tier();
            let mut blocks = 0u64;
            let mut rows: [&[f32]; simd::POINT_BLOCK] = [&[]; simd::POINT_BLOCK];
            let mut xns = [0f32; simd::POINT_BLOCK];
            let mut t0 = range.start;
            while t0 < range.end {
                let p = simd::POINT_BLOCK.min(range.end - t0);
                for o in 0..p {
                    let i = sel.nth(t0 + o);
                    rows[o] = m.row(i);
                    xns[o] = data.norms[i];
                }
                let base = t0 - range.start;
                simd::dist_rows_block_with(
                    tier,
                    &rows[..p],
                    &xns[..p],
                    &centroids.c,
                    &centroids.norms,
                    &mut out[base * k..(base + p) * k],
                );
                blocks += 1;
                t0 += p;
            }
            simd::note_dispatch(tier, blocks);
        }
        (_, Storage::Shard(_)) => unreachable!("shard chunks are staged above"),
    }
}

/// Validation-set mean MSE under `centroids` via any engine
/// (Σ min d² / n).
pub fn validation_mse(
    data: &Data,
    centroids: &Centroids,
    engine: &dyn AssignEngine,
    pool: &Pool,
) -> f64 {
    let (total, _) =
        engine.score(data, Sel::Range(0, data.n()), centroids, pool);
    total / data.n() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian::GaussianMixture;
    use crate::data::rcv1::Rcv1Sim;
    use crate::kmeans::init;
    use crate::util::propcheck::Cases;

    #[test]
    fn native_matches_bruteforce_and_parallel_matches_serial() {
        Cases::new(15).run(|rng| {
            let n = 100 + rng.below(900);
            let k = 2 + rng.below(10);
            let data = GaussianMixture::default_spec(k, 8)
                .generate(n, rng.next_u64());
            let cent = init::first_k(&data, k);
            let eng = NativeEngine::default();
            let mut l1 = vec![0u32; n];
            let mut d1 = vec![0f32; n];
            let calcs = eng.assign(
                &data,
                Sel::Range(0, n),
                &cent,
                &Pool::new(1),
                &mut l1,
                &mut d1,
            );
            assert_eq!(calcs, (n * k) as u64);
            let mut l4 = vec![0u32; n];
            let mut d4 = vec![0f32; n];
            eng.assign(&data, Sel::Range(0, n), &cent, &Pool::new(4), &mut l4, &mut d4);
            assert_eq!(l1, l4);
            assert_eq!(d1, d4);
            // spot-check against Data::nearest (per-point path must be
            // bit-identical to the blocked engine path)
            for i in (0..n).step_by(37) {
                let (j, d2) = data.nearest(i, &cent.c, &cent.norms);
                assert_eq!(l1[i], j);
                assert_eq!(d1[i], d2);
            }
        });
    }

    #[test]
    fn list_selection_matches_range() {
        let data = GaussianMixture::default_spec(3, 5).generate(50, 7);
        let cent = init::first_k(&data, 3);
        let eng = NativeEngine::default();
        let pool = Pool::new(2);
        let idx: Vec<usize> = (10..30).collect();
        let mut ll = vec![0u32; 20];
        let mut dl = vec![0f32; 20];
        eng.assign(&data, Sel::List(&idx), &cent, &pool, &mut ll, &mut dl);
        let mut lr = vec![0u32; 20];
        let mut dr = vec![0f32; 20];
        eng.assign(&data, Sel::Range(10, 30), &cent, &pool, &mut lr, &mut dr);
        assert_eq!(ll, lr);
        assert_eq!(dl, dr);
    }

    #[test]
    fn score_equals_sum_of_d2() {
        let data = GaussianMixture::default_spec(4, 6).generate(80, 3);
        let cent = init::first_k(&data, 4);
        let eng = NativeEngine::default();
        let pool = Pool::new(1);
        let (total, _) = eng.score(&data, Sel::Range(0, 80), &cent, &pool);
        let mse = validation_mse(&data, &cent, &eng, &pool);
        assert!((total / 80.0 - mse).abs() < 1e-12);
        let oracle = crate::kmeans::state::exact_mse(&data, &cent);
        assert!((mse - oracle).abs() < 1e-9 * (1.0 + oracle));
    }

    #[test]
    fn dist_rows_matches_pointwise() {
        let data = GaussianMixture::default_spec(3, 7).generate(40, 2);
        let cent = init::first_k(&data, 3);
        let mut out = vec![0f32; 40 * 3];
        let calcs = NativeEngine::default().dist_rows(
            &data,
            Sel::Range(0, 40),
            &cent,
            &Pool::new(3),
            &mut out,
        );
        assert_eq!(calcs, 120);
        for i in 0..40 {
            for j in 0..3 {
                let e = data.sq_dist_to(i, cent.c.row(j), cent.norms[j]);
                assert_eq!(out[i * 3 + j], e);
            }
        }
    }

    #[test]
    fn dist_rows_fans_out_at_100_rows() {
        // regression for the MIN_CHUNK.max(64) no-op: 100 rows on a
        // multi-thread pool must split into >1 chunk...
        let ranges = chunk_ranges(100, 4, DIST_ROWS_MIN_CHUNK);
        assert!(
            ranges.len() > 1,
            "100-row dist_rows stayed serial: {ranges:?}"
        );
        // ...and the fanned-out result must equal the serial one exactly
        let data = GaussianMixture::default_spec(4, 6).generate(100, 5);
        let cent = init::first_k(&data, 4);
        let mut par = vec![0f32; 100 * 4];
        let mut ser = vec![0f32; 100 * 4];
        NativeEngine::default().dist_rows(&data, Sel::Range(0, 100), &cent, &Pool::new(4), &mut par);
        NativeEngine::default().dist_rows(&data, Sel::Range(0, 100), &cent, &Pool::new(1), &mut ser);
        assert_eq!(par, ser);
    }

    #[test]
    fn transpose_cache_hits_and_invalidates() {
        let data = Rcv1Sim::default().generate(200, 3);
        let mut cent = init::first_k(&data, 10);
        let cache = TransCache::default();
        let a = cache.fetch(&cent);
        let b = cache.fetch(&cent);
        assert!(Arc::ptr_eq(&a, &b), "same revision must hit the cache");
        assert_eq!((cache.hits(), cache.builds()), (1, 1));
        cent.touch();
        let c = cache.fetch(&cent);
        assert!(!Arc::ptr_eq(&a, &c), "touch() must invalidate");
        // a clone shares the revision, so it also hits
        let clone = cent.clone();
        let d = cache.fetch(&clone);
        assert!(Arc::ptr_eq(&c, &d));
        assert_eq!((cache.hits(), cache.builds()), (2, 2));
    }

    #[test]
    fn per_engine_caches_do_not_evict_each_other() {
        // two sessions' engines interleaving sparse assigns (exactly
        // the multi-model serving pattern): each engine must build its
        // transpose once and hit thereafter. The old process-global
        // slot rebuilt on every alternation.
        let data_a = Rcv1Sim::default().generate(200, 1);
        let data_b = Rcv1Sim::default().generate(200, 2);
        let cent_a = init::first_k(&data_a, 10);
        let cent_b = init::first_k(&data_b, 10);
        let eng_a = NativeEngine::default();
        let eng_b = NativeEngine::default();
        let pool = Pool::new(2);
        let mut lbl = vec![0u32; 200];
        let mut d2 = vec![0f32; 200];
        for _ in 0..3 {
            eng_a.assign(&data_a, Sel::Range(0, 200), &cent_a, &pool, &mut lbl, &mut d2);
            eng_b.assign(&data_b, Sel::Range(0, 200), &cent_b, &pool, &mut lbl, &mut d2);
        }
        let (hits_a, builds_a) = eng_a.trans_cache_stats().unwrap();
        let (hits_b, builds_b) = eng_b.trans_cache_stats().unwrap();
        assert_eq!(builds_a, 1, "engine A rebuilt its unchanged transpose");
        assert_eq!(builds_b, 1, "engine B rebuilt its unchanged transpose");
        assert_eq!(hits_a, 2);
        assert_eq!(hits_b, 2);
        // a cloned engine shares the cache (same session handle)
        let clone_a = eng_a.clone();
        clone_a.assign(&data_a, Sel::Range(0, 200), &cent_a, &pool, &mut lbl, &mut d2);
        assert_eq!(eng_a.trans_cache_stats().unwrap(), (3, 1));
    }

    #[test]
    fn sparse_assign_tracks_centroid_updates_through_cache() {
        // end-to-end guard against stale transposes: assign, move the
        // centroids through the update path, assign again — results
        // must match the uncached per-point oracle both times
        let data = Rcv1Sim::default().generate(300, 9);
        let mut cent = init::first_k(&data, 12);
        let pool = Pool::new(2);
        let eng = NativeEngine::default();
        for round in 0..3 {
            let n = data.n();
            let mut lbl = vec![0u32; n];
            let mut d2 = vec![0f32; n];
            eng.assign(&data, Sel::Range(0, n), &cent, &pool, &mut lbl, &mut d2);
            for i in (0..n).step_by(29) {
                let (j, e) = data.nearest(i, &cent.c, &cent.norms);
                // transposed kernel may tie-break differently; distances
                // must agree to fp tolerance
                assert!(
                    (d2[i] - e).abs() <= 1e-3 * (1.0 + e.abs()),
                    "round {round} i={i}: {} vs oracle {e} (lbl {} vs {j})",
                    d2[i],
                    lbl[i]
                );
            }
            // move the centroids via the statistics path (bumps rev)
            let stats = crate::kmeans::par_add_stats(
                &data,
                Sel::Range(0, n),
                &lbl,
                &d2,
                12,
                &pool,
            );
            stats.update_centroids(&mut cent);
        }
    }

    #[test]
    fn sparse_assign_bit_identical_to_gather_oracle() {
        // the transposed + blocked + pruned path vs the per-point
        // gather path: AXPY lanes accumulate in spdot order, so labels
        // and distances must agree bit-for-bit (not just to tolerance)
        if simd::tier() == simd::Tier::Avx2Fma {
            return; // the opt-in FMA tier is documented as unfaithful
        }
        Cases::new(8).run(|rng| {
            let n = 200 + rng.below(300);
            let k = 8 + rng.below(12);
            let data = Rcv1Sim {
                vocab: 400,
                topic_vocab: 50,
                ..Default::default()
            }
            .generate(n, rng.next_u64());
            let cent = init::first_k(&data, k);
            let eng = NativeEngine::default();
            let pool = Pool::new(2);
            let mut lbl = vec![0u32; n];
            let mut d2 = vec![0f32; n];
            eng.assign(&data, Sel::Range(0, n), &cent, &pool, &mut lbl, &mut d2);
            // the transpose must actually be in play for this to test
            // the blocked path
            assert_eq!(eng.trans_cache_stats().unwrap().1, 1);
            for i in 0..n {
                let (j, e) = data.nearest(i, &cent.c, &cent.norms);
                assert_eq!(lbl[i], j, "label i={i}");
                assert_eq!(d2[i].to_bits(), e.to_bits(), "d2 i={i}");
            }
        });
    }

    #[test]
    fn warm_cache_serves_small_selections_without_building() {
        // the warm-path shortcut: a small (n < 64) sparse selection
        // would normally skip the transpose; once the cache holds the
        // current revision it must probe-hit and reuse it, never build
        if simd::tier() == simd::Tier::Avx2Fma {
            return; // the opt-in FMA tier is documented as unfaithful
        }
        let data = Rcv1Sim::default().generate(100, 4);
        let cent = init::first_k(&data, 10);
        let pool = Pool::new(1);
        let eng = NativeEngine::default();
        // warm the cache with one gate-passing selection
        let mut wl = vec![0u32; 100];
        let mut wd = vec![0f32; 100];
        eng.assign(&data, Sel::Range(0, 100), &cent, &pool, &mut wl, &mut wd);
        assert_eq!(eng.trans_cache_stats().unwrap(), (0, 1));
        let mut lbl = vec![0u32; 8];
        let mut d2 = vec![0f32; 8];
        eng.assign(&data, Sel::Range(0, 8), &cent, &pool, &mut lbl, &mut d2);
        eng.assign(&data, Sel::Range(0, 8), &cent, &pool, &mut lbl, &mut d2);
        assert_eq!(
            eng.trans_cache_stats().unwrap(),
            (2, 1),
            "warm engine must probe-hit small selections, never rebuild"
        );
        // the injected-transpose path (published-model predicts) serves
        // a cold engine without touching its cache at all
        let tc = eng.trans_handle(&cent).expect("gates pass");
        let inj = NativeEngine::default();
        let mut li = vec![0u32; 8];
        let mut di = vec![0f32; 8];
        inj.assign_with_trans(
            &data,
            Sel::Range(0, 8),
            &cent,
            &pool,
            Some(tc),
            &mut li,
            &mut di,
        );
        assert_eq!(
            inj.trans_cache_stats().unwrap(),
            (1, 0),
            "injected transpose must count a shared hit and no build"
        );
        // and the answers equal the cold gather path bitwise
        let plain = NativeEngine::default();
        let mut lbl2 = vec![0u32; 8];
        let mut d2b = vec![0f32; 8];
        plain.assign(&data, Sel::Range(0, 8), &cent, &pool, &mut lbl2, &mut d2b);
        assert_eq!(
            plain.trans_cache_stats().unwrap(),
            (0, 0),
            "a small cold selection must not build a transpose"
        );
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(lbl, lbl2);
        assert_eq!(li, lbl2);
        assert_eq!(bits(&d2), bits(&d2b));
        assert_eq!(bits(&di), bits(&d2b));
    }

    #[test]
    fn dense_auto_exponion_bit_identical_and_prunes_at_serving_k() {
        // serving-scale k crosses the Auto gate: the engine must build
        // the neighbour structure once, route every point through the
        // exponion path, evaluate strictly fewer centroids than n·k —
        // and stay bit-identical to the flat-scan engine
        if simd::tier() == simd::Tier::Avx2Fma {
            return; // the opt-in FMA tier is documented as unfaithful
        }
        let n = 700;
        let k = EXPONION_MIN_K + 88;
        let data = GaussianMixture::default_spec(8, 8).generate(n, 11);
        let cent = init::first_k(&data, k);
        let pool = Pool::new(2);
        let auto = NativeEngine::default();
        let flat = NativeEngine::default().with_strategy(Strategy::Flat);
        let mut la = vec![0u32; n];
        let mut da = vec![0f32; n];
        let mut lf = vec![0u32; n];
        let mut df = vec![0f32; n];
        auto.assign(&data, Sel::Range(0, n), &cent, &pool, &mut la, &mut da);
        flat.assign(&data, Sel::Range(0, n), &cent, &pool, &mut lf, &mut df);
        assert_eq!(la, lf);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&da), bits(&df));
        let (hits, builds, syncs) = auto.neigh_cache_stats().unwrap();
        assert_eq!((hits, builds, syncs), (0, 1, 0));
        let [(fp, _), (np, _), (ep, ee)] = auto.strategy_tally().snapshot();
        assert_eq!((fp, np), (0, 0), "auto must route all points to exponion");
        assert_eq!(ep, n as u64);
        assert!(
            ee < (n * k) as u64 / 2,
            "exponion evaluated {ee} of {} centroid distances",
            n * k
        );
        let [(fp2, fe2), ..] = flat.strategy_tally().snapshot();
        assert_eq!((fp2, fe2), (n as u64, (n * k) as u64));
        // second round at the same revision probe-hits, never rebuilds
        auto.assign(&data, Sel::Range(0, n), &cent, &pool, &mut la, &mut da);
        let (hits2, builds2, _) = auto.neigh_cache_stats().unwrap();
        assert_eq!((hits2, builds2), (1, 1));
    }

    #[test]
    fn sparse_exponion_engine_bit_identical_across_strategies() {
        // forced strategies on the same sparse batch must agree bit for
        // bit: exponion == norm-pruned == flat sweep == gather oracle
        if simd::tier() == simd::Tier::Avx2Fma {
            return; // the opt-in FMA tier is documented as unfaithful
        }
        let n = 300;
        let k = 24;
        let data = Rcv1Sim {
            vocab: 400,
            topic_vocab: 50,
            ..Default::default()
        }
        .generate(n, 5);
        let cent = init::first_k(&data, k);
        let pool = Pool::new(2);
        let mut out: Vec<(Vec<u32>, Vec<f32>)> = Vec::new();
        for s in [Strategy::Exponion, Strategy::Norm, Strategy::Flat] {
            let eng = NativeEngine::default().with_strategy(s);
            let mut lbl = vec![0u32; n];
            let mut d2 = vec![0f32; n];
            eng.assign(&data, Sel::Range(0, n), &cent, &pool, &mut lbl, &mut d2);
            let [(fp, _), (np, _), (ep, _)] = eng.strategy_tally().snapshot();
            let routed = match s {
                Strategy::Exponion => ep,
                Strategy::Norm => np,
                _ => fp,
            };
            assert_eq!(routed, n as u64, "{s:?} must route every point");
            out.push((lbl, d2));
        }
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        for (lbl, d2) in &out[1..] {
            assert_eq!(*lbl, out[0].0);
            assert_eq!(bits(d2), bits(&out[0].1));
        }
        for i in 0..n {
            let (j, e) = data.nearest(i, &cent.c, &cent.norms);
            assert_eq!(out[0].0[i], j);
            assert_eq!(out[0].1[i].to_bits(), e.to_bits());
        }
    }

    #[test]
    fn auto_runs_flat_scan_on_normalised_corpus() {
        // regression for the norm-prune overhead on unit-normalised
        // corpora: every norm bound collapses to the same value, so
        // Auto's precheck must pick the flat kernel — asserted through
        // strategy counters (dist-calc counts, not wall clock)
        let d = 64;
        let n = 200;
        let k = 16;
        let mut m = sparse::CsrMatrix::empty(d);
        for i in 0..n {
            // disjoint column ranges — CSR rows must not repeat a column
            let mut row = [
                ((i % 13) as u32, 1.0f32 + (i % 7) as f32),
                ((16 + i % 11) as u32, 2.0 + (i % 5) as f32),
                ((32 + i % 17) as u32, 0.5 + (i % 3) as f32),
            ];
            let nrm =
                row.iter().map(|(_, v)| v * v).sum::<f32>().sqrt();
            for (_, v) in row.iter_mut() {
                *v /= nrm;
            }
            m.push_row(&row);
        }
        let data = Data::sparse(m);
        let cent = init::first_k(&data, k);
        let eng = NativeEngine::default();
        let pool = Pool::new(2);
        let mut lbl = vec![0u32; n];
        let mut d2 = vec![0f32; n];
        eng.assign(&data, Sel::Range(0, n), &cent, &pool, &mut lbl, &mut d2);
        // the transpose must be in play (this is the blocked path)
        assert_eq!(eng.trans_cache_stats().unwrap(), (0, 1));
        let [(fp, fe), (np, _), _] = eng.strategy_tally().snapshot();
        assert_eq!(np, 0, "norm-pruning ran on a normalised corpus");
        assert_eq!(fp, n as u64);
        assert_eq!(
            fe,
            (n * k) as u64,
            "flat scan does exactly n·k evaluations — never more"
        );
    }

    #[test]
    fn trans_cache_reclaims_retired_blocks() {
        // publish-pinned rebuild cycle: a block pinned past its
        // revision parks on the free-list and is reclaimed — same
        // allocation, no fresh Vec — once the pin drops
        let data = Rcv1Sim::default().generate(200, 3);
        let mut cent = init::first_k(&data, 10);
        let cache = TransCache::default();
        let a = cache.fetch(&cent);
        let ptr_a = a.ct.as_ptr();
        cent.touch();
        // `a` still pinned (a published view would hold it like this):
        // the new revision must get a fresh allocation
        let b = cache.fetch(&cent);
        assert!(!std::ptr::eq(ptr_a, b.ct.as_ptr()));
        drop(a);
        cent.touch();
        // the pin is gone: this rebuild must reuse a's allocation
        let c = cache.fetch(&cent);
        assert!(
            std::ptr::eq(ptr_a, c.ct.as_ptr()),
            "retired block was not reclaimed"
        );
        assert_eq!(cache.builds(), 3, "reclaim still counts as a fill");
        drop(b);
        drop(c);
    }

    #[test]
    fn injected_neigh_handle_serves_cold_engine_without_builds() {
        // the published-model predict pattern: the training engine's
        // neighbour structure rides into a cold predict engine, which
        // must use it (counted as a shared hit), never build its own,
        // and answer bit-identically to the flat scan
        if simd::tier() == simd::Tier::Avx2Fma {
            return; // the opt-in FMA tier is documented as unfaithful
        }
        let n = 200;
        let k = 64;
        let data = GaussianMixture::default_spec(8, 8).generate(n, 23);
        let cent = init::first_k(&data, k);
        let pool = Pool::new(1);
        let train = NativeEngine::default().with_strategy(Strategy::Exponion);
        let ni = train.neigh_handle(&cent).expect("forced strategy builds");
        assert_eq!(train.neigh_cache_stats().unwrap(), (0, 1, 0));
        let predict = NativeEngine::default();
        let mut lp = vec![0u32; n];
        let mut dp = vec![0f32; n];
        predict.assign_with_handles(
            &data,
            Sel::Range(0, n),
            &cent,
            &pool,
            None,
            Some(ni),
            &mut lp,
            &mut dp,
        );
        assert_eq!(
            predict.neigh_cache_stats().unwrap(),
            (1, 0, 0),
            "injected structure must count a shared hit and no build"
        );
        let [_, _, (ep, _)] = predict.strategy_tally().snapshot();
        assert_eq!(ep, n as u64, "predict must route through exponion");
        let flat = NativeEngine::default().with_strategy(Strategy::Flat);
        let mut lf = vec![0u32; n];
        let mut df = vec![0f32; n];
        flat.assign(&data, Sel::Range(0, n), &cent, &pool, &mut lf, &mut df);
        assert_eq!(lp, lf);
        let bits = |v: &[f32]| v.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&dp), bits(&df));
    }

    #[test]
    fn empty_selection_ok() {
        let data = GaussianMixture::default_spec(2, 3).generate(5, 0);
        let cent = init::first_k(&data, 2);
        let mut l = [];
        let mut d = [];
        let c = NativeEngine::default().assign(
            &data,
            Sel::Range(2, 2),
            &cent,
            &Pool::new(4),
            &mut l,
            &mut d,
        );
        assert_eq!(c, 0);
    }
}
