//! Online serving walkthrough: train → snapshot → resume → stream in
//! fresh data → predict, exercising the whole `serve` layer in-process
//! (the `nmbkm train/serve/predict` subcommands drive the same code over
//! stdio/TCP).
//!
//! ```bash
//! cargo run --release --example online_serving
//! ```

use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::data::gaussian::GaussianMixture;
use nmbkm::serve::{protocol, session, ModelRegistry, Snapshot};

fn rows_of(data: &nmbkm::data::Data, lo: usize, hi: usize) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(hi - lo);
    let mut row = vec![0f32; data.dim()];
    for i in lo..hi {
        data.write_row_dense(i, &mut row);
        out.push(row.clone());
    }
    out
}

fn main() -> anyhow::Result<()> {
    // 12k points; the first 8k are the "historical" corpus, the rest
    // arrive later as live traffic
    let full = GaussianMixture::default_spec(8, 16).generate(12_000, 7);
    let history = full.slice(0, 8_000);

    let cfg = RunConfig {
        algo: Algo::TbRho,
        rho: Rho::Infinite,
        k: 8,
        b0: 512,
        max_rounds: 40,
        max_seconds: 3.0,
        threads: std::thread::available_parallelism()?.get(),
        ..Default::default()
    };

    // 1. train on the historical corpus and persist the model
    let (trained, report) = session::train(&history, &cfg)?;
    println!(
        "trained {} rounds over n={} (train MSE {:.4})",
        report.rounds_run,
        history.n(),
        report.last.map(|i| i.train_mse).unwrap_or(f64::NAN)
    );
    let path = std::env::temp_dir().join("nmbkm-online-serving-demo.json");
    trained.snapshot(true)?.save(&path)?;
    println!(
        "snapshot: {} ({} bytes)",
        path.display(),
        std::fs::metadata(&path)?.len()
    );

    // 2. a fresh process resumes the snapshot...
    let mut server = session::OnlineSession::resume(Snapshot::load(&path)?)?;
    println!("resumed: {}", server.stats_json().to_string());

    // 3. ...and digests the live stream in chunks, nested-batch style:
    //    every new point enters the statistics exactly once, when the
    //    growth controller votes to expand over it
    for chunk in 0..4 {
        let lo = 8_000 + chunk * 1_000;
        server.ingest_rows(&rows_of(&full, lo, lo + 1_000))?;
        let rep = server.step(5, 1.0)?;
        let info = rep.last.expect("stepped at least once");
        println!(
            "chunk {chunk}: n={} batch={} train MSE {:.4} ({} rounds)",
            server.data().n(),
            info.batch,
            info.train_mse,
            rep.rounds_run
        );
    }

    // 4. predict over the wire format (one JSONL request per line)
    let queries = rows_of(&full, 0, 3);
    let mut points = String::from("[");
    for (t, q) in queries.iter().enumerate() {
        if t > 0 {
            points.push(',');
        }
        points.push('[');
        let coords: Vec<String> = q.iter().map(|x| format!("{x}")).collect();
        points.push_str(&coords.join(","));
        points.push(']');
    }
    points.push(']');
    let request = format!("{{\"op\":\"predict\",\"points\":{points}}}");
    // requests route through the model registry; a bare session becomes
    // the implicit "default" model
    let registry = ModelRegistry::with_default(server);
    let (response, _) = protocol::handle_line(&registry, &request);
    println!("predict request : {request}");
    println!("predict response: {}", response.to_string());

    std::fs::remove_file(&path).ok();
    Ok(())
}
