//! Observability acceptance: the `{"op":"metrics"}` response keeps a
//! stable per-kind key schema, its counters are monotone across
//! requests, the Prometheus rendering of the same sample set passes the
//! exposition validator, and — the load-bearing invariant — enabling
//! metrics never perturbs a single predict bit on any dispatch tier.

use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::data::{Data, Storage};
use nmbkm::linalg::simd::{self, Tier};
use nmbkm::serve::wire::sparse_points_json;
use nmbkm::serve::{observe, protocol, session, ModelRegistry};
use nmbkm::util::json::Json;

fn cfg(algo: Algo, k: usize, b0: usize, rounds: usize) -> RunConfig {
    RunConfig {
        algo,
        k,
        b0,
        rho: Rho::Infinite,
        threads: 2,
        seed: 19,
        max_rounds: rounds,
        max_seconds: 60.0,
        eval_every_secs: 0.0,
        ..Default::default()
    }
}

fn sparse_corpus(n: usize, seed: u64) -> Data {
    nmbkm::data::rcv1::Rcv1Sim {
        vocab: 400,
        topic_vocab: 50,
        ..Default::default()
    }
    .generate(n, seed)
}

fn sparse_rows(data: &Data, lo: usize, hi: usize) -> Vec<(Vec<u32>, Vec<f32>)> {
    let Storage::Sparse(m) = &data.storage else {
        panic!("corpus must be sparse");
    };
    (lo..hi)
        .map(|i| {
            let (idx, vals) = m.row(i);
            (idx.to_vec(), vals.to_vec())
        })
        .collect()
}

fn dense_rows(data: &Data, lo: usize, hi: usize) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(hi - lo);
    let mut row = vec![0f32; data.dim()];
    for i in lo..hi {
        data.write_row_dense(i, &mut row);
        out.push(row.clone());
    }
    out
}

fn serve_one(reg: &ModelRegistry, req: &str) -> Json {
    let mut out = Vec::new();
    protocol::serve_lines(reg, std::io::Cursor::new(format!("{req}\n")), &mut out)
        .unwrap();
    Json::parse(String::from_utf8(out).unwrap().trim()).unwrap()
}

/// The value of one counter sample in a metrics response, summed over
/// every label set it appears under.
fn counter_total(doc: &Json, name: &str) -> f64 {
    doc.get("metrics")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .filter(|m| m.get("name").and_then(Json::as_str) == Some(name))
        .map(|m| m.get("value").and_then(Json::as_f64).unwrap_or(0.0))
        .sum()
}

fn has_series(doc: &Json, name: &str, label: Option<(&str, &str)>) -> bool {
    doc.get("metrics").unwrap().as_arr().unwrap().iter().any(|m| {
        let name_hit = m.get("name").and_then(Json::as_str) == Some(name);
        let label_hit = match label {
            None => true,
            Some((k, v)) => {
                m.get("labels").and_then(|l| l.get(k)).and_then(Json::as_str)
                    == Some(v)
            }
        };
        name_hit && label_hit
    })
}

#[test]
fn metrics_op_schema_stable_and_counters_monotone() {
    let data = sparse_corpus(500, 7);
    let (s, _) = session::train(&data, &cfg(Algo::GbRho, 8, 128, 5)).unwrap();
    let reg = ModelRegistry::with_default(s);
    let sparse = sparse_rows(&data, 0, 16);
    let predict_req = format!(
        "{{\"op\":\"predict\",\"points\":{}}}",
        sparse_points_json(data.dim(), &sparse)
    );

    let resp = serve_one(&reg, &predict_req);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");

    let m1 = serve_one(&reg, r#"{"op":"metrics"}"#);
    assert_eq!(m1.get("ok").unwrap().as_bool(), Some(true), "{m1:?}");
    assert_eq!(m1.get("op").unwrap().as_str(), Some("metrics"));
    assert_eq!(m1.get("schema").unwrap().as_f64(), Some(1.0));

    // per-kind key schema is frozen: dashboards key on these exact sets
    let samples = m1.get("metrics").unwrap().as_arr().unwrap();
    assert!(!samples.is_empty());
    for sample in samples {
        let Json::Obj(map) = sample else {
            panic!("metric sample is not an object: {sample:?}")
        };
        let keys: Vec<&str> = map.keys().map(|k| k.as_str()).collect();
        match sample.get("type").and_then(Json::as_str) {
            Some("counter") | Some("gauge") => {
                assert_eq!(
                    keys,
                    ["labels", "name", "type", "value"],
                    "scalar sample schema drifted: {sample:?}"
                );
            }
            Some("histogram") => {
                assert_eq!(
                    keys,
                    [
                        "buckets", "count", "labels", "name", "p50_s",
                        "p90_s", "p99_s", "sum_est_s", "type"
                    ],
                    "histogram sample schema drifted: {sample:?}"
                );
            }
            other => panic!("unknown sample type {other:?} in {sample:?}"),
        }
    }

    // the acceptance series: per-model predict counts, request op
    // counters, the sparse prune tallies, and the SIMD dispatch tally
    assert!(has_series(&m1, "nmbkm_requests_total", Some(("op", "predict"))));
    assert!(has_series(
        &m1,
        "nmbkm_model_predict_requests_total",
        Some(("model", "default"))
    ));
    assert!(has_series(&m1, "nmbkm_request_seconds", None));
    assert!(has_series(
        &m1,
        "nmbkm_model_predict_seconds",
        Some(("model", "default"))
    ));
    assert!(has_series(&m1, "nmbkm_sparse_prune_points_gathered_total", None));
    assert!(has_series(&m1, "nmbkm_sparse_prune_centroids_skipped_total", None));
    assert!(has_series(&m1, "nmbkm_simd_dispatch_total", None));
    assert!(has_series(
        &m1,
        "nmbkm_trans_cache_hits_total",
        Some(("engine", "predict"))
    ));
    // a sparse predict went through the transposed-centroid kernels, so
    // the prune counters saw its points
    assert!(counter_total(&m1, "nmbkm_sparse_prune_points_gathered_total") > 0.0);

    // monotonicity: more traffic can only grow _total series
    let predicts_before = counter_total(&m1, "nmbkm_model_predict_requests_total");
    let rows_before = counter_total(&m1, "nmbkm_model_predict_rows_total");
    for _ in 0..3 {
        let r = serve_one(&reg, &predict_req);
        assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));
    }
    let m2 = serve_one(&reg, r#"{"op":"metrics"}"#);
    let predicts_after = counter_total(&m2, "nmbkm_model_predict_requests_total");
    let rows_after = counter_total(&m2, "nmbkm_model_predict_rows_total");
    assert!(
        predicts_after >= predicts_before + 3.0,
        "predict counter not monotone: {predicts_before} -> {predicts_after}"
    );
    assert!(
        rows_after >= rows_before + 3.0 * 16.0,
        "predict row counter undercounts: {rows_before} -> {rows_after}"
    );
    // metrics requests count themselves too
    assert!(counter_total(&m2, "nmbkm_requests_total") > counter_total(&m1, "nmbkm_requests_total"));
}

#[test]
fn prometheus_rendering_validates_and_covers_the_registry() {
    let data = sparse_corpus(400, 11);
    let (s, _) = session::train(&data, &cfg(Algo::TbRho, 6, 64, 4)).unwrap();
    let reg = ModelRegistry::with_default(s);
    let sparse = sparse_rows(&data, 0, 8);
    let r = serve_one(
        &reg,
        &format!(
            "{{\"op\":\"predict\",\"points\":{}}}",
            sparse_points_json(data.dim(), &sparse)
        ),
    );
    assert_eq!(r.get("ok").unwrap().as_bool(), Some(true));

    let text = observe::render_prometheus(&reg);
    let summary = nmbkm::obs::export::validate_exposition(&text)
        .unwrap_or_else(|e| panic!("invalid exposition: {e}\n{text}"));
    assert!(summary.families >= 5, "suspiciously few families: {summary:?}");
    assert!(summary.series >= summary.families);
    assert!(text.contains("# TYPE nmbkm_requests_total counter"));
    assert!(text.contains("# TYPE nmbkm_request_seconds histogram"));
    assert!(text.contains("nmbkm_request_seconds_bucket{le=\"+Inf\"}"));
    assert!(text.contains("nmbkm_simd_dispatch_total{tier="));

    // both exposures read the same merged sample set: every Prometheus
    // family name appears in the JSON report too
    let doc = observe::metrics_json(&reg);
    for line in text.lines().filter(|l| l.starts_with("# TYPE ")) {
        let fam = line.split_whitespace().nth(2).unwrap();
        let found = doc
            .get("metrics")
            .unwrap()
            .as_arr()
            .unwrap()
            .iter()
            .any(|m| m.get("name").and_then(Json::as_str) == Some(fam));
        assert!(found, "family {fam} missing from the JSON report");
    }
}

#[test]
fn predicts_bit_exact_with_metrics_enabled_on_every_tier() {
    // the recording discipline keeps every counter flush outside kernel
    // arithmetic; predicts must not move by a bit whether metrics are
    // enabled or disabled, on the scalar tier and on the autodetected one
    if simd::tier() == Tier::Avx2Fma {
        return; // the opt-in FMA tier is documented as not bit-exact
    }
    let data = sparse_corpus(600, 13);
    let (s, _) = session::train(&data, &cfg(Algo::GbRho, 8, 128, 4)).unwrap();
    let reg = ModelRegistry::with_default(s);
    let entry = reg.resolve(None).unwrap();
    let queries = dense_rows(&data, 50, 114);

    let mut per_tier = Vec::new();
    for forced in [Some(Tier::Scalar), None] {
        simd::force_tier(forced);
        nmbkm::obs::set_enabled(true);
        let (l_on, d_on) = entry.predict(&queries).unwrap();
        nmbkm::obs::set_enabled(false);
        let (l_off, d_off) = entry.predict(&queries).unwrap();
        nmbkm::obs::set_enabled(true);
        assert_eq!(l_on, l_off, "labels moved with metrics toggled ({forced:?})");
        assert_eq!(
            d_on.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            d_off.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            "d2 bits moved with metrics toggled ({forced:?})"
        );
        per_tier.push((l_on, d_on));
    }
    simd::force_tier(None);
    // and the scalar tier agrees with the dispatched tier bit-for-bit,
    // metrics on — the PR 4 invariant survives instrumentation
    let (sl, sd) = &per_tier[0];
    let (al, ad) = &per_tier[1];
    assert_eq!(sl, al, "scalar vs dispatched labels diverged");
    assert_eq!(
        sd.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        ad.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
        "scalar vs dispatched d2 bits diverged"
    );
}
