"""AOT exporter: lower the L2 programs to HLO text + manifest.

Run once at build time (``make artifacts``); the rust runtime
(``rust/src/runtime/``) loads the manifest, compiles each HLO module on
the PJRT CPU client, and dispatches batches by shape. HLO *text* is the
interchange format — the image's xla_extension 0.5.1 rejects jax≥0.5
serialized protos (64-bit instruction ids), while the text parser
reassigns ids and round-trips cleanly (/opt/xla-example/README.md).

Usage: python -m compile.aot --out-dir ../artifacts
"""

import argparse
import functools
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# Compiled shape menu. Rust pads every batch up to the smallest (b, d, k)
# entry that fits; k=64 covers the paper's k=50, d=784 is infMNIST,
# d=64 serves the quickstart/gaussian workloads. Two batch tiles: a big
# 2048-row tile for throughput and a 256-row tile for remainders.
BATCHES = (2048, 256)
DIMS = (64, 784)
K = 64


def to_hlo_text(lowered) -> str:
    """stablehlo → XlaComputation → HLO text (return_tuple for rust)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _sig(args):
    return [[str(a.dtype), list(a.shape)] for a in args]


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_entries():
    """(name, fn, example_args, outputs) for every exported program.

    Perf note (EXPERIMENTS.md §Perf): exporting with tile_b = B (one
    grid step) was tried and measured perf-neutral on CPU-PJRT (XLA
    unrolls/fuses the 8-step interpret loop), so the TPU-shaped
    TILE_B=256 BlockSpec tiling is kept.
    """
    entries = []
    for b in BATCHES:
        for d in DIMS:
            x = _spec((b, d))
            c = _spec((K, d))
            cn = _spec((K,))
            lbl = _spec((b,), jnp.int32)
            d2 = _spec((b,))
            entries.append((
                f"assign_b{b}_d{d}_k{K}", model.assign_fn, (x, c, cn),
                [["int32", [b]], ["float32", [b]]],
            ))
            entries.append((
                f"assign_stats_b{b}_d{d}_k{K}", model.assign_stats_fn,
                (x, c, cn),
                [["int32", [b]], ["float32", [b]], ["float32", [K, d]],
                 ["float32", [K]], ["float32", [K]]],
            ))
            entries.append((
                f"stats_b{b}_d{d}_k{K}",
                functools.partial(model.stats_fn, k=K), (x, lbl, d2),
                [["float32", [K, d]], ["float32", [K]], ["float32", [K]]],
            ))
            entries.append((
                f"vmse_b{b}_d{d}_k{K}", model.validation_mse_fn, (x, c, cn),
                [["float32", []]],
            ))
            entries.append((
                f"distmat_b{b}_d{d}_k{K}", model.distmat_fn, (x, c, cn),
                [["float32", [b, K]]],
            ))
        lb = _spec((b, K))
        p = _spec((K,))
        dd = _spec((b,))
        lbl = _spec((b,), jnp.int32)
        entries.append((
            f"screen_b{b}_k{K}", model.screen_fn, (lb, p, dd, lbl),
            [["float32", [b, K]], ["int32", [b]]],
        ))
    return entries


def input_fingerprint():
    """Hash of the compile-path sources; lets `make artifacts` skip when
    nothing changed (recorded in the manifest)."""
    here = os.path.dirname(os.path.abspath(__file__))
    h = hashlib.sha256()
    for root, _, files in sorted(os.walk(here)):
        for f in sorted(files):
            if f.endswith(".py"):
                with open(os.path.join(root, f), "rb") as fh:
                    h.update(fh.read())
    return h.hexdigest()


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", default=None,
                    help="substring filter on entry names (debugging)")
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    manifest = {
        "k": K, "batches": list(BATCHES), "dims": list(DIMS),
        "fingerprint": input_fingerprint(), "entries": [],
    }
    for name, fn, example_args, outputs in build_entries():
        if args.only and args.only not in name:
            continue
        lowered = model.lower(fn, *example_args)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out_dir, fname), "w") as f:
            f.write(text)
        manifest["entries"].append({
            "name": name, "file": fname,
            "inputs": _sig(example_args), "outputs": outputs,
        })
        print(f"lowered {name}: {len(text)} chars")

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote manifest with {len(manifest['entries'])} entries")


if __name__ == "__main__":
    main()
