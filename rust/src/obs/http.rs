//! A hand-rolled HTTP/1.x responder for the `--metrics-addr` endpoint,
//! plus the matching one-shot client (CI scrapes and tests). Serving
//! metrics needs exactly one verb and two routes, so this stays a
//! ~hundred lines of `std::net` instead of a web framework: the same
//! no-dependency posture as the rest of the crate.

use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::Arc;
use std::time::Duration;

/// Content type Prometheus scrapers expect from a text exposition.
pub const PROMETHEUS_CTYPE: &str = "text/plain; version=0.0.4; charset=utf-8";

/// Route handler: path → `(content_type, body)`, `None` → 404.
pub type Renderer = Arc<dyn Fn(&str) -> Option<(&'static str, String)> + Send + Sync>;

/// Accept-loop over an already-bound listener, one short-lived thread
/// per scrape (scrapes are rare and tiny; connection reuse would buy
/// nothing). Runs until the process exits — the serve CLI holds the
/// returned handle only to keep it named.
pub fn spawn_metrics_server(
    listener: TcpListener,
    render: Renderer,
) -> std::thread::JoinHandle<()> {
    std::thread::spawn(move || {
        for conn in listener.incoming() {
            let Ok(stream) = conn else { continue };
            let render = render.clone();
            std::thread::spawn(move || {
                let _ = handle(stream, &render);
            });
        }
    })
}

fn handle(mut stream: TcpStream, render: &Renderer) -> Result<()> {
    stream.set_read_timeout(Some(Duration::from_secs(5)))?;
    let mut head = Vec::new();
    let mut buf = [0u8; 4096];
    loop {
        let n = stream.read(&mut buf)?;
        if n == 0 {
            break;
        }
        head.extend_from_slice(&buf[..n]);
        let done = head.windows(4).any(|w| w == b"\r\n\r\n")
            || head.windows(2).any(|w| w == b"\n\n");
        if done || head.len() > 16 * 1024 {
            break;
        }
    }
    let text = String::from_utf8_lossy(&head);
    let mut first = text.lines().next().unwrap_or("").split_whitespace();
    let method = first.next().unwrap_or("");
    let path = first.next().unwrap_or("/");
    let (status, ctype, body) = if method != "GET" {
        (
            "405 Method Not Allowed",
            "text/plain; charset=utf-8",
            "only GET is served here\n".to_string(),
        )
    } else {
        match render(path) {
            Some((ct, body)) => ("200 OK", ct, body),
            None => (
                "404 Not Found",
                "text/plain; charset=utf-8",
                format!("no route {path} (try /metrics or /metrics.json)\n"),
            ),
        }
    };
    write!(
        stream,
        "HTTP/1.1 {status}\r\nContent-Type: {ctype}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len()
    )?;
    stream.flush()?;
    Ok(())
}

/// One-shot `GET {path}` against `addr`, returning `(status, body)`.
/// HTTP/1.0 with `Connection: close`, so reading to EOF delimits the
/// body without chunked-encoding machinery.
pub fn http_get(addr: &str, path: &str) -> Result<(u16, String)> {
    let mut stream =
        TcpStream::connect(addr).with_context(|| format!("connecting {addr}"))?;
    stream.set_read_timeout(Some(Duration::from_secs(10)))?;
    write!(
        stream,
        "GET {path} HTTP/1.0\r\nHost: {addr}\r\nConnection: close\r\n\r\n"
    )?;
    stream.flush()?;
    let mut resp = Vec::new();
    stream.read_to_end(&mut resp)?;
    let text = String::from_utf8_lossy(&resp).into_owned();
    let (head, body) = text
        .split_once("\r\n\r\n")
        .unwrap_or((text.as_str(), ""));
    let status: u16 = head
        .lines()
        .next()
        .and_then(|l| l.split_whitespace().nth(1))
        .and_then(|c| c.parse().ok())
        .with_context(|| format!("malformed HTTP response from {addr}"))?;
    Ok((status, body.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_routes_and_scrapes_back() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let render: Renderer = Arc::new(|path| match path {
            "/metrics" => Some((PROMETHEUS_CTYPE, "# TYPE up gauge\nup 1\n".to_string())),
            "/metrics.json" => Some(("application/json", "{\"schema\":1}".to_string())),
            _ => None,
        });
        let _server = spawn_metrics_server(listener, render);
        let (code, body) = http_get(&addr, "/metrics").unwrap();
        assert_eq!(code, 200);
        assert_eq!(body, "# TYPE up gauge\nup 1\n");
        let (code, body) = http_get(&addr, "/metrics.json").unwrap();
        assert_eq!(code, 200);
        assert!(body.contains("schema"));
        let (code, _) = http_get(&addr, "/nope").unwrap();
        assert_eq!(code, 404);
    }
}
