//! Event-driven TCP serving: a dependency-free readiness loop (raw
//! epoll on Linux, kqueue on the BSD family) that scales to thousands
//! of connections without a thread — or a thread stack — per socket.
//!
//! ## Architecture
//!
//! ```text
//!            ┌──────────┐   round-robin    ┌─────────────┐
//!  accept ──▶│ acceptor │─────────────────▶│ shard loops │──┐ decoded
//!            │ (poller) │  admission:      │ (N pollers) │  │ requests
//!            └──────────┘  --max-conns     └─────────────┘  ▼
//!                                            ▲  response  ┌─────────┐
//!                                            └────────────│ workers │
//!                                               bytes     └─────────┘
//! ```
//!
//! * The **acceptor** owns the (nonblocking) listener on its own mini
//!   poller. Accepted sockets are admitted against `--max-conns` and
//!   handed round-robin to a shard; over-limit peers get a structured
//!   `overloaded` JSONL error and an immediate close — never a hang.
//!   Between accept bursts it runs the registry's model-lifecycle tick
//!   (idle eviction + the `--max-resident` LRU cap).
//! * **N shard loops** own the connections: nonblocking reads into a
//!   per-connection buffer, incremental JSONL / binary-frame delimiting
//!   (`frame::scan_frame_total`), wire-format negotiation on the first
//!   byte, and bounded per-connection write queues. A decoded request
//!   is handed to the worker pool; strictly **one request per
//!   connection is in flight**, so responses come back in request order
//!   and the stream is byte-identical to the thread-per-connection
//!   implementation (the blocking `serve_lines`/`serve_frames` remain
//!   the stdio reference path).
//! * **W workers** execute requests against the shared registry —
//!   predict fan-out inside `ModelEntry::predict_wire` reuses
//!   `coordinator::shard::Pool::run_jobs`, so the CPU parallelism of a
//!   big batch is the model pool's, not the transport's — and push the
//!   encoded response bytes back to the owning shard through its inbox
//!   and wake pipe.
//!
//! ## Backpressure and admission
//!
//! A peer that stops reading fills its write queue; past the cap the
//! shard **stops reading from that peer** (`nmbkm_conn_backpressure_total`)
//! until the queue drains below half — so a slow consumer throttles
//! itself, never a core or a session lock. `--max-inflight` bounds the
//! number of dispatched-but-unanswered requests across all connections,
//! and `--max-request-bytes` bounds a single request (oversized JSONL
//! lines are discarded to the newline, oversized frames are skipped by
//! their own length prefix — the stream survives with an `overloaded`
//! error either way).
//!
//! ## Shutdown
//!
//! `shutdown` (from any connection, either framing) flips a stop flag
//! and **wakes every poller through its wake pipe** — no loopback
//! self-connect, no race with `accept()`. Drain order: stop accepting →
//! stop reading → finish in-flight requests → flush write queues →
//! close → WAL drain (`server::drain_wal`).
//!
//! Idle timeouts replace the old per-socket `SO_RCVTIMEO`: under a
//! nonblocking loop `WouldBlock` is the normal idle state, so stalls
//! are detected by a clock sweep over `last_activity` instead of by
//! classifying error strings (the old `is_timeout` textual matcher is
//! gone). Connections idle past `--conn-timeout` with no request in
//! flight still count on `nmbkm_connection_timeouts_total`.

use crate::obs::log as obslog;
use crate::serve::frame;
use crate::serve::observe::serve_metrics;
use crate::serve::protocol::{self, LineReply, Request};
use crate::serve::registry::ModelRegistry;
use crate::serve::server::{self, ServeOptions};
use crate::util::json::{self, Json};
use anyhow::{anyhow, Result};
use std::collections::HashMap;
use std::io::{self, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::fd::{AsRawFd, RawFd};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::{Duration, Instant};

/// Default per-connection write-queue cap (`ServeOptions::write_queue_cap
/// == 0`). Generous: a queue only grows past the kernel socket buffer
/// when the peer stops reading.
pub const DEFAULT_WRITE_QUEUE: usize = 4 << 20;

/// Soft cap on a connection's read buffer while a request is in flight:
/// pipelined requests beyond it wait in the kernel (read interest off)
/// until the current response is handed back.
const INBUF_SOFT_CAP: usize = 1 << 20;

/// One nonblocking read drains at most this much per readiness event so
/// a firehose peer cannot starve its shard siblings.
const READ_CHUNK: usize = 16 << 10;
const MAX_READS_PER_EVENT: usize = 16;

/// Poller wait tick: drives the idle-timeout sweep and the lifecycle
/// tick even when no fd is ready.
const WAIT_TICK: Duration = Duration::from_millis(200);

/// How long a draining shard waits for in-flight requests to finish and
/// write queues to flush before force-closing.
const DRAIN_GRACE: Duration = Duration::from_secs(5);

/// Cadence of the acceptor's model-lifecycle tick (idle eviction and
/// the LRU residency cap).
const LIFECYCLE_TICK: Duration = Duration::from_secs(1);

// ── syscall layer ────────────────────────────────────────────────────
//
// Thin `extern "C"` declarations against the platform libc that std
// already links — no crate dependency. Only what the poller needs:
// epoll/kqueue, a self-pipe for wake tokens, and rlimit for the
// saturating bench's fd headroom.

#[cfg(any(target_os = "linux", target_os = "android"))]
mod sys {
    #![allow(non_camel_case_types)]

    pub const EPOLLIN: u32 = 0x001;
    pub const EPOLLOUT: u32 = 0x004;
    pub const EPOLLERR: u32 = 0x008;
    pub const EPOLLHUP: u32 = 0x010;
    pub const EPOLL_CTL_ADD: i32 = 1;
    pub const EPOLL_CTL_DEL: i32 = 2;
    pub const EPOLL_CTL_MOD: i32 = 3;
    const EPOLL_CLOEXEC: i32 = 0x80000;
    const O_NONBLOCK: i32 = 0x800;
    const O_CLOEXEC: i32 = 0x80000;
    pub const RLIMIT_NOFILE: i32 = 7;

    // x86-64's ABI packs epoll_event (32-bit alignment); every other
    // Linux arch uses natural alignment
    #[cfg(target_arch = "x86_64")]
    #[repr(C, packed)]
    #[derive(Clone, Copy)]
    pub struct epoll_event {
        pub events: u32,
        pub data: u64,
    }
    #[cfg(not(target_arch = "x86_64"))]
    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct epoll_event {
        pub events: u32,
        pub data: u64,
    }

    #[repr(C)]
    pub struct rlimit {
        pub rlim_cur: u64,
        pub rlim_max: u64,
    }

    extern "C" {
        fn epoll_create1(flags: i32) -> i32;
        fn epoll_ctl(epfd: i32, op: i32, fd: i32, event: *mut epoll_event) -> i32;
        fn epoll_wait(
            epfd: i32,
            events: *mut epoll_event,
            maxevents: i32,
            timeout_ms: i32,
        ) -> i32;
        fn pipe2(fds: *mut i32, flags: i32) -> i32;
        fn close(fd: i32) -> i32;
        fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        fn write(fd: i32, buf: *const u8, count: usize) -> isize;
        fn getrlimit(resource: i32, rlim: *mut rlimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const rlimit) -> i32;
    }

    pub fn poll_create() -> std::io::Result<i32> {
        let fd = unsafe { epoll_create1(EPOLL_CLOEXEC) };
        if fd < 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(fd)
    }

    pub fn poll_ctl(epfd: i32, op: i32, fd: i32, events: u32, token: u64) -> std::io::Result<()> {
        let mut ev = epoll_event { events, data: token };
        let arg = if op == EPOLL_CTL_DEL { std::ptr::null_mut() } else { &mut ev };
        if unsafe { epoll_ctl(epfd, op, fd, arg) } != 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok(())
    }

    pub fn poll_wait(
        epfd: i32,
        events: &mut [epoll_event],
        timeout_ms: i32,
    ) -> std::io::Result<usize> {
        let n = unsafe {
            epoll_wait(epfd, events.as_mut_ptr(), events.len() as i32, timeout_ms)
        };
        if n < 0 {
            let e = std::io::Error::last_os_error();
            if e.kind() == std::io::ErrorKind::Interrupted {
                return Ok(0);
            }
            return Err(e);
        }
        Ok(n as usize)
    }

    pub fn wake_pipe() -> std::io::Result<(i32, i32)> {
        let mut fds = [0i32; 2];
        if unsafe { pipe2(fds.as_mut_ptr(), O_NONBLOCK | O_CLOEXEC) } != 0 {
            return Err(std::io::Error::last_os_error());
        }
        Ok((fds[0], fds[1]))
    }

    pub fn close_fd(fd: i32) {
        unsafe { close(fd) };
    }

    pub fn read_fd(fd: i32, buf: &mut [u8]) -> isize {
        unsafe { read(fd, buf.as_mut_ptr(), buf.len()) }
    }

    pub fn write_fd(fd: i32, buf: &[u8]) -> isize {
        unsafe { write(fd, buf.as_ptr(), buf.len()) }
    }

    pub fn nofile_limits() -> Option<(u64, u64)> {
        let mut rl = rlimit { rlim_cur: 0, rlim_max: 0 };
        if unsafe { getrlimit(RLIMIT_NOFILE, &mut rl) } != 0 {
            return None;
        }
        Some((rl.rlim_cur, rl.rlim_max))
    }

    pub fn set_nofile_soft(cur: u64, max: u64) -> bool {
        let rl = rlimit { rlim_cur: cur, rlim_max: max };
        unsafe { setrlimit(RLIMIT_NOFILE, &rl) == 0 }
    }
}

#[cfg(any(
    target_os = "macos",
    target_os = "ios",
    target_os = "freebsd",
    target_os = "netbsd",
    target_os = "openbsd",
    target_os = "dragonfly"
))]
mod sys {
    #![allow(non_camel_case_types)]

    pub const EVFILT_READ: i16 = -1;
    pub const EVFILT_WRITE: i16 = -2;
    pub const EV_ADD: u16 = 0x1;
    pub const EV_DELETE: u16 = 0x2;
    pub const EV_ENABLE: u16 = 0x4;
    pub const EV_EOF: u16 = 0x8000;
    pub const EV_ERROR: u16 = 0x4000;
    const F_SETFL: i32 = 4;
    const F_SETFD: i32 = 2;
    const FD_CLOEXEC: i32 = 1;
    const O_NONBLOCK: i32 = 4;
    #[cfg(any(target_os = "macos", target_os = "ios"))]
    pub const RLIMIT_NOFILE: i32 = 8;
    #[cfg(not(any(target_os = "macos", target_os = "ios")))]
    pub const RLIMIT_NOFILE: i32 = 8;

    #[repr(C)]
    #[derive(Clone, Copy)]
    pub struct kevent_t {
        pub ident: usize,
        pub filter: i16,
        pub flags: u16,
        pub fflags: u32,
        pub data: isize,
        pub udata: usize,
    }

    #[repr(C)]
    pub struct timespec {
        pub tv_sec: i64,
        pub tv_nsec: i64,
    }

    #[repr(C)]
    pub struct rlimit {
        pub rlim_cur: u64,
        pub rlim_max: u64,
    }

    extern "C" {
        fn kqueue() -> i32;
        fn kevent(
            kq: i32,
            changelist: *const kevent_t,
            nchanges: i32,
            eventlist: *mut kevent_t,
            nevents: i32,
            timeout: *const timespec,
        ) -> i32;
        fn pipe(fds: *mut i32) -> i32;
        fn fcntl(fd: i32, cmd: i32, arg: i32) -> i32;
        fn close(fd: i32) -> i32;
        fn read(fd: i32, buf: *mut u8, count: usize) -> isize;
        fn write(fd: i32, buf: *const u8, count: usize) -> isize;
        fn getrlimit(resource: i32, rlim: *mut rlimit) -> i32;
        fn setrlimit(resource: i32, rlim: *const rlimit) -> i32;
    }

    pub fn poll_create() -> std::io::Result<i32> {
        let kq = unsafe { kqueue() };
        if kq < 0 {
            return Err(std::io::Error::last_os_error());
        }
        unsafe { fcntl(kq, F_SETFD, FD_CLOEXEC) };
        Ok(kq)
    }

    fn change(kq: i32, fd: i32, filter: i16, flags: u16, token: u64) -> i32 {
        let ch = kevent_t {
            ident: fd as usize,
            filter,
            flags,
            fflags: 0,
            data: 0,
            udata: token as usize,
        };
        unsafe { kevent(kq, &ch, 1, std::ptr::null_mut(), 0, std::ptr::null()) }
    }

    /// Set the exact (readable, writable) interest for `fd`; stale
    /// filters are deleted (a missing filter is not an error).
    pub fn set_interest(
        kq: i32,
        fd: i32,
        token: u64,
        readable: bool,
        writable: bool,
    ) -> std::io::Result<()> {
        for (filter, want) in [(EVFILT_READ, readable), (EVFILT_WRITE, writable)] {
            if want {
                if change(kq, fd, filter, EV_ADD | EV_ENABLE, token) < 0 {
                    return Err(std::io::Error::last_os_error());
                }
            } else {
                let _ = change(kq, fd, filter, EV_DELETE, token);
            }
        }
        Ok(())
    }

    pub fn poll_wait(
        kq: i32,
        events: &mut [kevent_t],
        timeout_ms: i32,
    ) -> std::io::Result<usize> {
        let ts = timespec {
            tv_sec: (timeout_ms / 1000) as i64,
            tv_nsec: (timeout_ms % 1000) as i64 * 1_000_000,
        };
        let n = unsafe {
            kevent(
                kq,
                std::ptr::null(),
                0,
                events.as_mut_ptr(),
                events.len() as i32,
                &ts,
            )
        };
        if n < 0 {
            let e = std::io::Error::last_os_error();
            if e.kind() == std::io::ErrorKind::Interrupted {
                return Ok(0);
            }
            return Err(e);
        }
        Ok(n as usize)
    }

    pub fn wake_pipe() -> std::io::Result<(i32, i32)> {
        let mut fds = [0i32; 2];
        if unsafe { pipe(fds.as_mut_ptr()) } != 0 {
            return Err(std::io::Error::last_os_error());
        }
        for fd in fds {
            unsafe {
                fcntl(fd, F_SETFL, O_NONBLOCK);
                fcntl(fd, F_SETFD, FD_CLOEXEC);
            }
        }
        Ok((fds[0], fds[1]))
    }

    pub fn close_fd(fd: i32) {
        unsafe { close(fd) };
    }

    pub fn read_fd(fd: i32, buf: &mut [u8]) -> isize {
        unsafe { read(fd, buf.as_mut_ptr(), buf.len()) }
    }

    pub fn write_fd(fd: i32, buf: &[u8]) -> isize {
        unsafe { write(fd, buf.as_ptr(), buf.len()) }
    }

    pub fn nofile_limits() -> Option<(u64, u64)> {
        let mut rl = rlimit { rlim_cur: 0, rlim_max: 0 };
        if unsafe { getrlimit(RLIMIT_NOFILE, &mut rl) } != 0 {
            return None;
        }
        Some((rl.rlim_cur, rl.rlim_max))
    }

    pub fn set_nofile_soft(cur: u64, max: u64) -> bool {
        let rl = rlimit { rlim_cur: cur, rlim_max: max };
        unsafe { setrlimit(RLIMIT_NOFILE, &rl) == 0 }
    }
}

#[cfg(not(any(
    target_os = "linux",
    target_os = "android",
    target_os = "macos",
    target_os = "ios",
    target_os = "freebsd",
    target_os = "netbsd",
    target_os = "openbsd",
    target_os = "dragonfly"
)))]
mod sys {
    // Platforms without a readiness syscall we wrap: the crate still
    // builds, the TCP server reports the gap at runtime.
    pub fn unsupported<T>() -> std::io::Result<T> {
        Err(std::io::Error::new(
            std::io::ErrorKind::Unsupported,
            "the event-driven server needs epoll or kqueue",
        ))
    }
    pub fn nofile_limits() -> Option<(u64, u64)> {
        None
    }
    pub fn set_nofile_soft(_cur: u64, _max: u64) -> bool {
        false
    }
}

/// Raise the process's soft `RLIMIT_NOFILE` toward `want` (capped at
/// the hard limit); returns the resulting soft limit. The saturating
/// bench calls this before opening thousands of sockets.
pub fn raise_nofile_limit(want: u64) -> u64 {
    let Some((cur, max)) = sys::nofile_limits() else {
        return 1024;
    };
    if cur >= want {
        return cur;
    }
    let target = want.min(max);
    if sys::set_nofile_soft(target, max) {
        target
    } else {
        cur
    }
}

// ── poller ───────────────────────────────────────────────────────────

/// Token reserved for the wake pipe ([`Poller::wait`] drains it and
/// never emits it).
const WAKE: u64 = u64::MAX;
/// Token for the acceptor's listener.
const LISTENER: u64 = u64::MAX - 1;

/// One readiness report.
#[derive(Clone, Copy, Debug)]
struct Event {
    token: u64,
    readable: bool,
    writable: bool,
    /// Error/hangup readiness: the owner should attempt I/O and let the
    /// resulting `io::ErrorKind` (not a string match) classify it.
    err: bool,
}

/// The write end of a poller's self-pipe, `Arc`-owned so late wakers
/// (a worker finishing after its shard drained) hit a still-valid fd —
/// never a recycled one. Writes after the read end closed are `EPIPE`,
/// which Rust's runtime already ignores.
struct WakeFd(RawFd);

impl Drop for WakeFd {
    fn drop(&mut self) {
        #[cfg(any(
            target_os = "linux",
            target_os = "android",
            target_os = "macos",
            target_os = "ios",
            target_os = "freebsd",
            target_os = "netbsd",
            target_os = "openbsd",
            target_os = "dragonfly"
        ))]
        sys::close_fd(self.0);
    }
}

#[derive(Clone)]
struct Waker(Arc<WakeFd>);

impl Waker {
    fn wake(&self) {
        #[cfg(any(
            target_os = "linux",
            target_os = "android",
            target_os = "macos",
            target_os = "ios",
            target_os = "freebsd",
            target_os = "netbsd",
            target_os = "openbsd",
            target_os = "dragonfly"
        ))]
        {
            let _ = sys::write_fd(self.0 .0, &[1u8]);
        }
    }
}

/// A readiness poller (epoll / kqueue) with a built-in wake pipe.
struct Poller {
    pfd: RawFd,
    wake_rx: RawFd,
    waker: Waker,
}

#[cfg(any(target_os = "linux", target_os = "android"))]
impl Poller {
    fn new() -> io::Result<Poller> {
        let pfd = sys::poll_create()?;
        let (rx, tx) = sys::wake_pipe()?;
        sys::poll_ctl(pfd, sys::EPOLL_CTL_ADD, rx, sys::EPOLLIN, WAKE)?;
        Ok(Poller { pfd, wake_rx: rx, waker: Waker(Arc::new(WakeFd(tx))) })
    }

    fn add(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        sys::poll_ctl(self.pfd, sys::EPOLL_CTL_ADD, fd, interest(readable, writable), token)
    }

    fn modify(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        sys::poll_ctl(self.pfd, sys::EPOLL_CTL_MOD, fd, interest(readable, writable), token)
    }

    fn del(&self, fd: RawFd) {
        let _ = sys::poll_ctl(self.pfd, sys::EPOLL_CTL_DEL, fd, 0, 0);
    }

    fn wait(&self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<()> {
        out.clear();
        let mut evs = [sys::epoll_event { events: 0, data: 0 }; 256];
        let n = sys::poll_wait(self.pfd, &mut evs, timeout.as_millis() as i32)?;
        for ev in evs.iter().take(n) {
            let (bits, token) = { (ev.events, ev.data) };
            if token == WAKE {
                self.drain_wake();
                continue;
            }
            out.push(Event {
                token,
                readable: bits & sys::EPOLLIN != 0,
                writable: bits & sys::EPOLLOUT != 0,
                err: bits & (sys::EPOLLERR | sys::EPOLLHUP) != 0,
            });
        }
        Ok(())
    }

    fn drain_wake(&self) {
        let mut buf = [0u8; 64];
        while sys::read_fd(self.wake_rx, &mut buf) > 0 {}
    }
}

#[cfg(any(target_os = "linux", target_os = "android"))]
fn interest(readable: bool, writable: bool) -> u32 {
    let mut bits = 0;
    if readable {
        bits |= sys::EPOLLIN;
    }
    if writable {
        bits |= sys::EPOLLOUT;
    }
    bits
}

#[cfg(any(
    target_os = "macos",
    target_os = "ios",
    target_os = "freebsd",
    target_os = "netbsd",
    target_os = "openbsd",
    target_os = "dragonfly"
))]
impl Poller {
    fn new() -> io::Result<Poller> {
        let pfd = sys::poll_create()?;
        let (rx, tx) = sys::wake_pipe()?;
        sys::set_interest(pfd, rx, WAKE, true, false)?;
        Ok(Poller { pfd, wake_rx: rx, waker: Waker(Arc::new(WakeFd(tx))) })
    }

    fn add(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        sys::set_interest(self.pfd, fd, token, readable, writable)
    }

    fn modify(&self, fd: RawFd, token: u64, readable: bool, writable: bool) -> io::Result<()> {
        sys::set_interest(self.pfd, fd, token, readable, writable)
    }

    fn del(&self, fd: RawFd) {
        let _ = sys::set_interest(self.pfd, fd, 0, false, false);
    }

    fn wait(&self, out: &mut Vec<Event>, timeout: Duration) -> io::Result<()> {
        out.clear();
        let mut evs = [sys::kevent_t {
            ident: 0,
            filter: 0,
            flags: 0,
            fflags: 0,
            data: 0,
            udata: 0,
        }; 256];
        let n = sys::poll_wait(self.pfd, &mut evs, timeout.as_millis() as i32)?;
        for ev in evs.iter().take(n) {
            let token = ev.udata as u64;
            if token == WAKE {
                self.drain_wake();
                continue;
            }
            out.push(Event {
                token,
                readable: ev.filter == sys::EVFILT_READ,
                writable: ev.filter == sys::EVFILT_WRITE,
                err: ev.flags & (sys::EV_EOF | sys::EV_ERROR) != 0,
            });
        }
        Ok(())
    }

    fn drain_wake(&self) {
        let mut buf = [0u8; 64];
        while sys::read_fd(self.wake_rx, &mut buf) > 0 {}
    }
}

#[cfg(not(any(
    target_os = "linux",
    target_os = "android",
    target_os = "macos",
    target_os = "ios",
    target_os = "freebsd",
    target_os = "netbsd",
    target_os = "openbsd",
    target_os = "dragonfly"
)))]
impl Poller {
    fn new() -> io::Result<Poller> {
        sys::unsupported()
    }
    fn add(&self, _: RawFd, _: u64, _: bool, _: bool) -> io::Result<()> {
        sys::unsupported()
    }
    fn modify(&self, _: RawFd, _: u64, _: bool, _: bool) -> io::Result<()> {
        sys::unsupported()
    }
    fn del(&self, _: RawFd) {}
    fn wait(&self, _: &mut Vec<Event>, _: Duration) -> io::Result<()> {
        sys::unsupported()
    }
}

impl Drop for Poller {
    fn drop(&mut self) {
        #[cfg(any(
            target_os = "linux",
            target_os = "android",
            target_os = "macos",
            target_os = "ios",
            target_os = "freebsd",
            target_os = "netbsd",
            target_os = "openbsd",
            target_os = "dragonfly"
        ))]
        {
            sys::close_fd(self.wake_rx);
            sys::close_fd(self.pfd);
        }
    }
}

// ── shared server state ──────────────────────────────────────────────

enum ShardMsg {
    /// A freshly admitted connection (already nonblocking).
    Conn(TcpStream, String),
    /// A worker's encoded response for `token`.
    Reply { token: u64, bytes: Vec<u8>, quit: bool },
}

struct ShardHandle {
    inbox: Mutex<Vec<ShardMsg>>,
    waker: Waker,
}

enum Work {
    /// A parsed JSONL request (response is a JSONL line, or a
    /// magic-prefixed frame for `"binary":true` predicts).
    Line(Request),
    /// A parsed binary-frame request (response is a frame).
    Frame(Request),
}

struct Job {
    shard: usize,
    token: u64,
    work: Work,
}

struct Shared {
    registry: Arc<ModelRegistry>,
    opts: ServeOptions,
    stop: AtomicBool,
    /// Dispatched-but-unanswered requests across all connections.
    inflight: AtomicUsize,
    /// Open (admitted) connections, for `--max-conns`.
    open: AtomicUsize,
    shards: Vec<ShardHandle>,
    acceptor_waker: Waker,
}

impl Shared {
    fn send_to_shard(&self, shard: usize, msg: ShardMsg) {
        self.shards[shard].inbox.lock().unwrap().push(msg);
        self.shards[shard].waker.wake();
    }

    fn request_stop(&self) {
        self.stop.store(true, Ordering::SeqCst);
        self.acceptor_waker.wake();
        for s in &self.shards {
            s.waker.wake();
        }
    }

    fn write_queue_cap(&self) -> usize {
        if self.opts.write_queue_cap == 0 {
            DEFAULT_WRITE_QUEUE
        } else {
            self.opts.write_queue_cap
        }
    }
}

fn overloaded_line(reason: &str) -> Vec<u8> {
    let resp = protocol::err_json(&anyhow!("overloaded: {reason}"));
    let mut bytes = resp.to_string().into_bytes();
    bytes.push(b'\n');
    bytes
}

fn overloaded_frame(reason: &str) -> Vec<u8> {
    let resp = protocol::err_json(&anyhow!("overloaded: {reason}"));
    let mut out = Vec::new();
    let written = frame::write_frame(&mut out, &resp, &[]).unwrap_or(0);
    serve_metrics().frame_bytes_written.add(written as u64);
    out
}

// ── connection state machine ─────────────────────────────────────────

#[derive(Clone, Copy, PartialEq)]
enum Mode {
    Negotiating,
    Jsonl,
    Frame,
}

struct Conn {
    stream: TcpStream,
    peer: String,
    mode: Mode,
    inbuf: Vec<u8>,
    outbuf: Vec<u8>,
    outpos: usize,
    /// One request dispatched, response not yet queued.
    busy: bool,
    /// Peer half-closed its write side (read returned 0).
    eof: bool,
    close_after_flush: bool,
    backpressured: bool,
    /// JSONL line over `--max-request-bytes`: drop bytes to the newline.
    discard_line: bool,
    /// Oversized frame: bytes of it left to swallow.
    skip: usize,
    last_activity: Instant,
    want_read: bool,
    want_write: bool,
}

impl Conn {
    fn new(stream: TcpStream, peer: String) -> Conn {
        Conn {
            stream,
            peer,
            mode: Mode::Negotiating,
            inbuf: Vec::new(),
            outbuf: Vec::new(),
            outpos: 0,
            busy: false,
            eof: false,
            close_after_flush: false,
            backpressured: false,
            discard_line: false,
            skip: 0,
            last_activity: Instant::now(),
            want_read: true,
            want_write: false,
        }
    }

    fn queued(&self) -> usize {
        self.outbuf.len() - self.outpos
    }

    fn consume_in(&mut self, n: usize) {
        self.inbuf.drain(..n);
    }
}

/// Why a connection is being closed — drives the counter/obslog parity
/// with the old thread-per-connection handler.
enum Close {
    Clean,
    Timeout,
    Error(String),
}

// ── the server ───────────────────────────────────────────────────────

/// Serve `listener` with the event loop until a client sends
/// `shutdown`. This is `serve_listener_with`'s engine; behaviour on the
/// wire is byte-identical to the old thread-per-connection loop.
pub(crate) fn run(
    registry: Arc<ModelRegistry>,
    listener: TcpListener,
    opts: ServeOptions,
) -> Result<()> {
    let par = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4);
    let nshards = (par / 2).clamp(1, 4);
    let nworkers = par.clamp(2, 8);

    let acceptor_poller = Poller::new().map_err(io_err("creating poller"))?;
    let mut shard_pollers = Vec::with_capacity(nshards);
    let mut handles = Vec::with_capacity(nshards);
    for _ in 0..nshards {
        let p = Poller::new().map_err(io_err("creating shard poller"))?;
        handles.push(ShardHandle {
            inbox: Mutex::new(Vec::new()),
            waker: p.waker.clone(),
        });
        shard_pollers.push(p);
    }
    let shared = Arc::new(Shared {
        registry: registry.clone(),
        opts,
        stop: AtomicBool::new(false),
        inflight: AtomicUsize::new(0),
        open: AtomicUsize::new(0),
        shards: handles,
        acceptor_waker: acceptor_poller.waker.clone(),
    });

    // worker pool: a shared MPMC queue (mutexed mpsc receiver) feeding
    // W executor threads; batch fan-out inside predict_wire reuses the
    // model pools' run_jobs
    let (job_tx, job_rx) = mpsc::channel::<Job>();
    let job_rx = Arc::new(Mutex::new(job_rx));
    let mut worker_threads = Vec::with_capacity(nworkers);
    for w in 0..nworkers {
        let shared = shared.clone();
        let rx = job_rx.clone();
        worker_threads.push(
            std::thread::Builder::new()
                .name(format!("nmbkm-worker-{w}"))
                .spawn(move || worker_loop(&shared, &rx))
                .map_err(|e| anyhow!("spawning worker: {e}"))?,
        );
    }

    let mut shard_threads = Vec::with_capacity(nshards);
    for (id, poller) in shard_pollers.into_iter().enumerate() {
        let shared = shared.clone();
        let tx = job_tx.clone();
        shard_threads.push(
            std::thread::Builder::new()
                .name(format!("nmbkm-shard-{id}"))
                .spawn(move || shard_loop(&shared, id, poller, tx))
                .map_err(|e| anyhow!("spawning shard: {e}"))?,
        );
    }
    drop(job_tx); // workers exit once every shard's sender is gone

    accept_loop(&shared, &listener, &acceptor_poller);

    // drain: shards finish in-flight work and flush; workers run dry
    for s in &shared.shards {
        s.waker.wake();
    }
    for t in shard_threads {
        let _ = t.join();
    }
    for t in worker_threads {
        let _ = t.join();
    }
    server::drain_wal(&registry);
    Ok(())
}

fn io_err(what: &'static str) -> impl Fn(io::Error) -> anyhow::Error {
    move |e| anyhow!("{what}: {e}")
}

// ── acceptor ─────────────────────────────────────────────────────────

fn accept_loop(shared: &Shared, listener: &TcpListener, poller: &Poller) {
    let sm = serve_metrics();
    if let Err(e) = listener.set_nonblocking(true) {
        eprintln!("[nmbkm::serve] nonblocking listener: {e}");
        return;
    }
    if let Err(e) = poller.add(listener.as_raw_fd(), LISTENER, true, false) {
        eprintln!("[nmbkm::serve] registering listener: {e}");
        return;
    }
    let mut rr = 0usize;
    let mut events = Vec::new();
    let mut next_lifecycle = Instant::now() + LIFECYCLE_TICK;
    loop {
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        if poller.wait(&mut events, WAIT_TICK).is_err() {
            break;
        }
        if shared.stop.load(Ordering::SeqCst) {
            break;
        }
        for ev in &events {
            if ev.token != LISTENER || !(ev.readable || ev.err) {
                continue;
            }
            loop {
                match listener.accept() {
                    Ok((stream, addr)) => {
                        let peer = addr.to_string();
                        sm.conns_opened.inc();
                        eprintln!("[nmbkm::serve] client {peer} connected");
                        obslog::event("connection_open", &[("peer", json::s(&peer))]);
                        let max = shared.opts.max_conns;
                        if max > 0 && shared.open.load(Ordering::SeqCst) >= max {
                            // structured refusal instead of a hang: the
                            // socket is still blocking here, and the
                            // one-line write fits any socket buffer
                            sm.overloaded_conns.inc();
                            let line = overloaded_line(&format!(
                                "connection limit reached (--max-conns={max})"
                            ));
                            let _ = (&stream).write_all(&line);
                            sm.conns_closed.inc();
                            obslog::event(
                                "connection_close",
                                &[
                                    ("peer", json::s(&peer)),
                                    ("clean", Json::Bool(true)),
                                ],
                            );
                            continue;
                        }
                        if let Err(e) = stream.set_nonblocking(true) {
                            eprintln!("[nmbkm::serve] nonblocking conn: {e}");
                            sm.conns_closed.inc();
                            continue;
                        }
                        shared.open.fetch_add(1, Ordering::SeqCst);
                        sm.open_connections.inc();
                        shared.send_to_shard(rr, ShardMsg::Conn(stream, peer));
                        rr = (rr + 1) % shared.shards.len();
                    }
                    Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
                    Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
                    Err(e) => {
                        eprintln!("[nmbkm::serve] accept failed: {e}");
                        break;
                    }
                }
            }
        }
        // model lifecycle: idle eviction + the LRU residency cap, run
        // here (not in a shard) so a checkpoint-then-drop never stalls
        // connection I/O
        let now = Instant::now();
        if now >= next_lifecycle {
            next_lifecycle = now + LIFECYCLE_TICK;
            shared.registry.run_lifecycle();
        }
    }
    poller.del(listener.as_raw_fd());
}

// ── workers ──────────────────────────────────────────────────────────

fn worker_loop(shared: &Shared, rx: &Mutex<mpsc::Receiver<Job>>) {
    let sm = serve_metrics();
    loop {
        // hold the queue lock only for the dequeue, never the execution
        let job = match rx.lock().unwrap().recv() {
            Ok(j) => j,
            Err(_) => break,
        };
        let (bytes, quit) = match &job.work {
            Work::Line(req) => {
                let (reply, quit) = protocol::execute_line(&shared.registry, req);
                let bytes = match reply {
                    LineReply::Json(resp) => {
                        let resp = resp.to_string();
                        sm.jsonl_bytes_written.add(resp.len() as u64 + 1);
                        let mut b = resp.into_bytes();
                        b.push(b'\n');
                        b
                    }
                    LineReply::Frame(b) => {
                        sm.jsonl_bytes_written.add(b.len() as u64);
                        b
                    }
                };
                (bytes, quit)
            }
            Work::Frame(req) => {
                let (h, body, quit) = frame::execute_frame(&shared.registry, req);
                let mut out = Vec::new();
                let written = frame::write_frame(&mut out, &h, &body).unwrap_or(0);
                sm.frame_bytes_written.add(written as u64);
                (out, quit)
            }
        };
        shared.inflight.fetch_sub(1, Ordering::SeqCst);
        shared.send_to_shard(
            job.shard,
            ShardMsg::Reply { token: job.token, bytes, quit },
        );
    }
}

// ── shard event loop ─────────────────────────────────────────────────

struct Shard<'a> {
    shared: &'a Shared,
    id: usize,
    poller: Poller,
    job_tx: mpsc::Sender<Job>,
    conns: HashMap<u64, Conn>,
    next_token: u64,
}

fn shard_loop(shared: &Shared, id: usize, poller: Poller, job_tx: mpsc::Sender<Job>) {
    let mut shard = Shard {
        shared,
        id,
        poller,
        job_tx,
        conns: HashMap::new(),
        next_token: 0,
    };
    let mut events = Vec::new();
    let mut drain_deadline: Option<Instant> = None;
    loop {
        if shard.poller.wait(&mut events, WAIT_TICK).is_err() {
            break;
        }
        let evs = std::mem::take(&mut events);
        // inbox first: responses unblock pipelined requests before new
        // socket events are looked at
        let msgs: Vec<ShardMsg> = {
            let mut inbox = shared.shards[id].inbox.lock().unwrap();
            std::mem::take(&mut *inbox)
        };
        for msg in msgs {
            match msg {
                ShardMsg::Conn(stream, peer) => shard.register(stream, peer),
                ShardMsg::Reply { token, bytes, quit } => shard.on_reply(token, bytes, quit),
            }
        }
        for ev in &evs {
            shard.on_event(ev);
        }
        events = evs;
        shard.sweep_idle();
        if shared.stop.load(Ordering::SeqCst) {
            let deadline =
                *drain_deadline.get_or_insert_with(|| Instant::now() + DRAIN_GRACE);
            shard.drain_tick();
            if shard.conns.is_empty() {
                break;
            }
            if Instant::now() >= deadline {
                let tokens: Vec<u64> = shard.conns.keys().copied().collect();
                for t in tokens {
                    shard.close(t, Close::Clean);
                }
                break;
            }
        }
    }
}

impl Shard<'_> {
    fn register(&mut self, stream: TcpStream, peer: String) {
        let token = self.next_token;
        self.next_token += 1;
        let fd = stream.as_raw_fd();
        if let Err(e) = self.poller.add(fd, token, true, false) {
            eprintln!("[nmbkm::serve] registering {peer}: {e}");
            self.shared.open.fetch_sub(1, Ordering::SeqCst);
            let sm = serve_metrics();
            sm.open_connections.dec();
            sm.conns_closed.inc();
            return;
        }
        self.conns.insert(token, Conn::new(stream, peer));
    }

    fn on_reply(&mut self, token: u64, bytes: Vec<u8>, quit: bool) {
        // the connection may have died while its request ran; the old
        // implementation's write would have failed the same way
        let Some(conn) = self.conns.get_mut(&token) else {
            if quit {
                self.shared.request_stop();
            }
            return;
        };
        conn.busy = false;
        conn.last_activity = Instant::now();
        conn.outbuf.extend_from_slice(&bytes);
        if quit {
            // shutdown: the response still goes out to its requester
            conn.close_after_flush = true;
            self.shared.request_stop();
        }
        self.service(token);
    }

    fn on_event(&mut self, ev: &Event) {
        let Some(conn) = self.conns.get_mut(&ev.token) else {
            return;
        };
        if ev.readable || ev.err {
            if let Err(close) = read_some(conn) {
                self.close(ev.token, close);
                return;
            }
        }
        if ev.writable || ev.err {
            if let Err(close) = flush_some(conn) {
                self.close(ev.token, close);
                return;
            }
        }
        self.service(ev.token);
    }

    /// Pump the connection: decode/dispatch what the buffers allow,
    /// flush what the socket accepts, update poller interest, close if
    /// finished. The one per-connection driver after any state change.
    fn service(&mut self, token: u64) {
        let stopping = self.shared.stop.load(Ordering::SeqCst);
        if !self.conns.contains_key(&token) {
            return;
        }
        if !stopping {
            if let Err(close) = self.pump(token) {
                self.close(token, close);
                return;
            }
        }
        let Some(conn) = self.conns.get_mut(&token) else {
            return;
        };
        if let Err(close) = flush_some(conn) {
            self.close(token, close);
            return;
        }
        if conn.queued() == 0 && conn.close_after_flush {
            self.close(token, Close::Clean);
            return;
        }
        // EOF: everything decodable was dispatched; a leftover partial
        // frame is a truncation error (exactly like the blocking
        // read_frame_raw), a leftover JSONL fragment was already served
        // as the final line. Close once the response queue is flushed.
        if conn.eof && !conn.busy && conn.queued() == 0 {
            if conn.mode == Mode::Frame && !conn.inbuf.is_empty() {
                self.close(
                    token,
                    Close::Error("truncated frame: EOF inside a frame".to_string()),
                );
            } else {
                self.close(token, Close::Clean);
            }
            return;
        }
        self.update_interest(token);
    }

    /// Decode and dispatch requests from `inbuf` while the connection
    /// has none in flight.
    fn pump(&mut self, token: u64) -> std::result::Result<(), Close> {
        let sm = serve_metrics();
        loop {
            let conn = self.conns.get_mut(&token).expect("pumped conn exists");
            if conn.busy || conn.close_after_flush {
                return Ok(());
            }
            match conn.mode {
                Mode::Negotiating => {
                    let Some(&first) = conn.inbuf.first() else {
                        return Ok(());
                    };
                    if first == frame::MAGIC {
                        if self.shared.opts.accept_binary {
                            conn.consume_in(1);
                            conn.mode = Mode::Frame;
                        } else {
                            // refuse loudly in the client's only other
                            // dialect, then close — silence would look
                            // like a hang (same line as the blocking path)
                            let resp = json::obj(vec![
                                ("ok", Json::Bool(false)),
                                ("error", json::s(server::BINARY_DISABLED_MSG)),
                            ]);
                            conn.outbuf.extend_from_slice(resp.to_string().as_bytes());
                            conn.outbuf.push(b'\n');
                            conn.inbuf.clear();
                            conn.close_after_flush = true;
                            return Ok(());
                        }
                    } else {
                        conn.mode = Mode::Jsonl;
                    }
                }
                Mode::Jsonl => {
                    if conn.discard_line {
                        match conn.inbuf.iter().position(|&b| b == b'\n') {
                            Some(p) => {
                                conn.consume_in(p + 1);
                                conn.discard_line = false;
                            }
                            None => {
                                conn.inbuf.clear();
                                return Ok(());
                            }
                        }
                        continue;
                    }
                    let nl = conn.inbuf.iter().position(|&b| b == b'\n');
                    let raw = match nl {
                        Some(p) => {
                            let mut line: Vec<u8> = conn.inbuf[..p].to_vec();
                            conn.consume_in(p + 1);
                            // BufRead::lines strips \r\n; a lone \r at
                            // EOF stays, matching its read_line logic
                            if line.last() == Some(&b'\r') {
                                line.pop();
                            }
                            line
                        }
                        None => {
                            let cap = self.shared.opts.max_request_bytes;
                            if cap > 0 && conn.inbuf.len() > cap {
                                sm.overloaded_bytes.inc();
                                let reply = overloaded_line(&format!(
                                    "request line exceeds --max-request-bytes={cap}"
                                ));
                                sm.jsonl_bytes_written.add(reply.len() as u64);
                                conn.outbuf.extend_from_slice(&reply);
                                conn.inbuf.clear();
                                conn.discard_line = true;
                                continue;
                            }
                            if conn.eof && !conn.inbuf.is_empty() {
                                // final unterminated line: lines() yields
                                // it, so the event loop serves it too
                                std::mem::take(&mut conn.inbuf)
                            } else {
                                return Ok(());
                            }
                        }
                    };
                    let line = match String::from_utf8(raw) {
                        Ok(l) => l,
                        Err(_) => {
                            return Err(Close::Error(
                                "stream did not contain valid UTF-8".to_string(),
                            ))
                        }
                    };
                    if line.trim().is_empty() {
                        continue; // blank lines: skipped, never counted
                    }
                    sm.jsonl_bytes_read.add(line.len() as u64 + 1);
                    let cap = self.shared.opts.max_request_bytes;
                    if cap > 0 && line.len() > cap {
                        sm.overloaded_bytes.inc();
                        let reply = overloaded_line(&format!(
                            "request of {} bytes exceeds --max-request-bytes={cap}",
                            line.len()
                        ));
                        sm.jsonl_bytes_written.add(reply.len() as u64);
                        conn.outbuf.extend_from_slice(&reply);
                        continue;
                    }
                    match protocol::parse_request(&line) {
                        Ok(req) => self.dispatch(token, Work::Line(req)),
                        Err(e) => {
                            sm.op_counter("invalid").inc();
                            let resp = protocol::err_json(&e).to_string();
                            sm.jsonl_bytes_written.add(resp.len() as u64 + 1);
                            conn.outbuf.extend_from_slice(resp.as_bytes());
                            conn.outbuf.push(b'\n');
                        }
                    }
                }
                Mode::Frame => {
                    if conn.skip > 0 {
                        let take = conn.skip.min(conn.inbuf.len());
                        conn.consume_in(take);
                        conn.skip -= take;
                        if conn.skip > 0 {
                            return Ok(());
                        }
                        continue;
                    }
                    let total = match frame::scan_frame_total(&conn.inbuf) {
                        Ok(Some(t)) => t,
                        Ok(None) => return Ok(()),
                        Err(e) => return Err(Close::Error(format!("{e:#}"))),
                    };
                    let cap = self.shared.opts.max_request_bytes;
                    if cap > 0 && total > cap {
                        sm.frames.inc();
                        sm.frame_bytes_read.add(total as u64);
                        sm.overloaded_bytes.inc();
                        let reply = overloaded_frame(&format!(
                            "frame of {total} bytes exceeds --max-request-bytes={cap}"
                        ));
                        conn.outbuf.extend_from_slice(&reply);
                        let have = total.min(conn.inbuf.len());
                        conn.consume_in(have);
                        conn.skip = total - have;
                        continue;
                    }
                    if conn.inbuf.len() < total {
                        return Ok(());
                    }
                    sm.frames.inc();
                    sm.frame_bytes_read.add(total as u64);
                    let hlen =
                        u32::from_le_bytes(conn.inbuf[0..4].try_into().unwrap()) as usize;
                    let hbytes = conn.inbuf[4..4 + hlen].to_vec();
                    let body = conn.inbuf[8 + hlen..total].to_vec();
                    conn.consume_in(total);
                    let parsed = frame::parse_header(&hbytes)
                        .and_then(|h| frame::parse_frame_request(&h, &body));
                    match parsed {
                        Ok(req) => self.dispatch(token, Work::Frame(req)),
                        Err(e) => {
                            sm.op_counter("invalid").inc();
                            let mut out = Vec::new();
                            let written = frame::write_frame(
                                &mut out,
                                &protocol::err_json(&e),
                                &[],
                            )
                            .unwrap_or(0);
                            sm.frame_bytes_written.add(written as u64);
                            conn.outbuf.extend_from_slice(&out);
                        }
                    }
                }
            }
        }
    }

    /// Hand one decoded request to the workers (admission permitting).
    fn dispatch(&mut self, token: u64, work: Work) {
        let sm = serve_metrics();
        let max = self.shared.opts.max_inflight;
        let conn = self.conns.get_mut(&token).expect("dispatched conn exists");
        if max > 0 && self.shared.inflight.load(Ordering::SeqCst) >= max {
            sm.overloaded_inflight.inc();
            let reason =
                format!("server is at --max-inflight={max} concurrent requests");
            match work {
                Work::Line(_) => {
                    let reply = overloaded_line(&reason);
                    sm.jsonl_bytes_written.add(reply.len() as u64);
                    conn.outbuf.extend_from_slice(&reply);
                }
                Work::Frame(_) => {
                    conn.outbuf.extend_from_slice(&overloaded_frame(&reason));
                }
            }
            return;
        }
        self.shared.inflight.fetch_add(1, Ordering::SeqCst);
        conn.busy = true;
        conn.last_activity = Instant::now();
        if self
            .job_tx
            .send(Job { shard: self.id, token, work })
            .is_err()
        {
            // tearing down; the drain path closes the connection
            self.shared.inflight.fetch_sub(1, Ordering::SeqCst);
            conn.busy = false;
        }
    }

    fn update_interest(&mut self, token: u64) {
        let stopping = self.shared.stop.load(Ordering::SeqCst);
        let cap = self.shared.write_queue_cap();
        let sm = serve_metrics();
        let Some(conn) = self.conns.get_mut(&token) else {
            return;
        };
        let queued = conn.queued();
        if !conn.backpressured && queued > cap {
            conn.backpressured = true;
            sm.conn_backpressure.inc();
        } else if conn.backpressured && queued < cap / 2 {
            conn.backpressured = false;
        }
        let want_read = !conn.eof
            && !conn.close_after_flush
            && !conn.backpressured
            && !stopping
            && !(conn.busy && conn.inbuf.len() >= INBUF_SOFT_CAP);
        let want_write = queued > 0;
        if (want_read, want_write) != (conn.want_read, conn.want_write) {
            conn.want_read = want_read;
            conn.want_write = want_write;
            let fd = conn.stream.as_raw_fd();
            let _ = self.poller.modify(fd, token, want_read, want_write);
        }
    }

    /// Close idle-past-timeout connections. Only truly idle ones: a
    /// request in flight or a draining write queue is activity the old
    /// per-op socket timeouts never interrupted mid-compute either.
    fn sweep_idle(&mut self) {
        let Some(timeout) = self.shared.opts.conn_timeout else {
            return;
        };
        let now = Instant::now();
        let stale: Vec<u64> = self
            .conns
            .iter()
            .filter(|(_, c)| !c.busy && now.duration_since(c.last_activity) > timeout)
            .map(|(t, _)| *t)
            .collect();
        for token in stale {
            self.close(token, Close::Timeout);
        }
    }

    /// One drain pass while stopping: flush, close what's finished.
    fn drain_tick(&mut self) {
        let tokens: Vec<u64> = self.conns.keys().copied().collect();
        for token in tokens {
            let Some(conn) = self.conns.get_mut(&token) else {
                continue;
            };
            if let Err(close) = flush_some(conn) {
                self.close(token, close);
                continue;
            }
            let conn = self.conns.get_mut(&token).expect("drained conn exists");
            if !conn.busy && conn.queued() == 0 {
                self.close(token, Close::Clean);
            } else {
                self.update_interest(token);
            }
        }
    }

    fn close(&mut self, token: u64, why: Close) {
        let Some(conn) = self.conns.remove(&token) else {
            return;
        };
        self.poller.del(conn.stream.as_raw_fd());
        self.shared.open.fetch_sub(1, Ordering::SeqCst);
        let sm = serve_metrics();
        sm.open_connections.dec();
        sm.conns_closed.inc();
        let clean = match why {
            Close::Clean => true,
            Close::Timeout => {
                sm.conn_timeouts.inc();
                obslog::event("connection_timeout", &[("peer", json::s(&conn.peer))]);
                eprintln!(
                    "[nmbkm::serve] client {} timed out (idle past --conn-timeout)",
                    conn.peer
                );
                false
            }
            Close::Error(e) => {
                eprintln!("[nmbkm::serve] connection error: {e}");
                false
            }
        };
        obslog::event(
            "connection_close",
            &[("peer", json::s(&conn.peer)), ("clean", Json::Bool(clean))],
        );
        // conn.stream drops here, closing the socket
    }
}

/// Nonblocking read burst into `inbuf`. `Err` means the connection is
/// done (I/O error); EOF is recorded, not an error — under a readiness
/// loop `WouldBlock` is the normal idle state, classified by
/// `io::ErrorKind`, never by matching message strings.
fn read_some(conn: &mut Conn) -> std::result::Result<(), Close> {
    let mut buf = [0u8; READ_CHUNK];
    for _ in 0..MAX_READS_PER_EVENT {
        match (&conn.stream).read(&mut buf) {
            Ok(0) => {
                conn.eof = true;
                return Ok(());
            }
            Ok(n) => {
                conn.last_activity = Instant::now();
                conn.inbuf.extend_from_slice(&buf[..n]);
                if n < buf.len() {
                    return Ok(());
                }
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => return Ok(()),
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(Close::Error(e.to_string())),
        }
    }
    Ok(()) // level-triggered: the rest re-arms immediately
}

/// Flush as much of the write queue as the socket accepts.
fn flush_some(conn: &mut Conn) -> std::result::Result<(), Close> {
    while conn.outpos < conn.outbuf.len() {
        match (&conn.stream).write(&conn.outbuf[conn.outpos..]) {
            Ok(0) => return Err(Close::Error("write returned 0".to_string())),
            Ok(n) => {
                conn.outpos += n;
                conn.last_activity = Instant::now();
            }
            Err(e) if e.kind() == io::ErrorKind::WouldBlock => break,
            Err(e) if e.kind() == io::ErrorKind::Interrupted => continue,
            Err(e) => return Err(Close::Error(e.to_string())),
        }
    }
    if conn.outpos == conn.outbuf.len() {
        conn.outbuf.clear();
        conn.outpos = 0;
    } else if conn.outpos > DEFAULT_WRITE_QUEUE {
        conn.outbuf.drain(..conn.outpos);
        conn.outpos = 0;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn waker_unblocks_wait() {
        let p = Poller::new().unwrap();
        let waker = p.waker.clone();
        let t = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(30));
            waker.wake();
        });
        let mut events = Vec::new();
        let start = Instant::now();
        // a 5 s wait returns early on the wake, with no events emitted
        p.wait(&mut events, Duration::from_secs(5)).unwrap();
        assert!(start.elapsed() < Duration::from_secs(2));
        assert!(events.is_empty(), "wake token must be internal");
        t.join().unwrap();
    }

    #[test]
    fn poller_reports_pipe_like_readiness() {
        use std::io::Write as _;
        use std::net::TcpListener;
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let p = Poller::new().unwrap();
        let mut client = std::net::TcpStream::connect(addr).unwrap();
        let (server, _) = listener.accept().unwrap();
        server.set_nonblocking(true).unwrap();
        p.add(server.as_raw_fd(), 7, true, false).unwrap();
        client.write_all(b"x").unwrap();
        let mut events = Vec::new();
        let start = Instant::now();
        loop {
            p.wait(&mut events, Duration::from_millis(500)).unwrap();
            if !events.is_empty() {
                break;
            }
            assert!(start.elapsed() < Duration::from_secs(5), "no readiness");
        }
        assert_eq!(events[0].token, 7);
        assert!(events[0].readable);
        p.del(server.as_raw_fd());
    }

    #[test]
    fn nofile_raise_is_monotone() {
        let before = raise_nofile_limit(256);
        assert!(before >= 256 || sys::nofile_limits().is_none());
        // asking for less than we have never lowers the limit
        let after = raise_nofile_limit(16);
        assert!(after >= before.min(256));
    }
}
