//! Serve-layer throughput: predict QPS at 1 vs 4 concurrent TCP
//! connections **while the model trains**. The multi-connection server
//! answers predicts from published snapshots without touching the
//! session lock, so throughput should scale with connections instead of
//! serialising behind training rounds (`BENCH_serve.json`; CI runs
//! `--smoke` as a scaling sanity check, not a precision measurement).
//!
//! Usage: cargo bench --bench serve_throughput -- [--quick|--smoke]
//!        [--json BENCH_serve.json]

use nmbkm::bench::{BenchOpts, BenchReport, BenchSet};
use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::coordinator::Pool;
use nmbkm::data::gaussian::GaussianMixture;
use nmbkm::data::Data;
use nmbkm::serve::{session, ModelRegistry};
use nmbkm::util::json::{self, Json};
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

struct Scale {
    n_points: usize,
    k: usize,
    dim: usize,
    predicts_per_conn: usize,
    query_batch: usize,
}

fn scale_for(opts: &BenchOpts) -> Scale {
    if opts.samples <= 1 {
        // CI smoke: prove the concurrent path works, in milliseconds
        Scale { n_points: 2000, k: 10, dim: 16, predicts_per_conn: 30, query_batch: 8 }
    } else {
        Scale { n_points: 20000, k: 50, dim: 32, predicts_per_conn: 300, query_batch: 16 }
    }
}

fn cfg(k: usize) -> RunConfig {
    RunConfig {
        algo: Algo::TbRho,
        k,
        b0: 1024,
        rho: Rho::Infinite,
        threads: Pool::auto().threads.min(4),
        seed: 11,
        max_rounds: usize::MAX,
        max_seconds: f64::INFINITY,
        stop_on_convergence: false,
        ..Default::default()
    }
}

fn points_json(rows: &[Vec<f32>]) -> String {
    let coords: Vec<String> = rows
        .iter()
        .map(|q| {
            let xs: Vec<String> = q.iter().map(|x| format!("{x}")).collect();
            format!("[{}]", xs.join(","))
        })
        .collect();
    format!("[{}]", coords.join(","))
}

fn roundtrip(conn: &mut TcpStream, reader: &mut BufReader<TcpStream>, req: &str) -> Json {
    conn.write_all(req.as_bytes()).unwrap();
    conn.write_all(b"\n").unwrap();
    let mut line = String::new();
    reader.read_line(&mut line).unwrap();
    Json::parse(line.trim()).unwrap()
}

fn connect(addr: std::net::SocketAddr) -> (TcpStream, BufReader<TcpStream>) {
    let conn = TcpStream::connect(addr).unwrap();
    let reader = BufReader::new(conn.try_clone().unwrap());
    (conn, reader)
}

/// One trial: serve a training model over TCP; `conns` client threads
/// each complete `predicts_per_conn` predict requests while a driver
/// connection keeps issuing training steps. Returns when every client
/// finished (the timed region).
fn run_trial(data: &Data, scale: &Scale, conns: usize) {
    let queries: Vec<Vec<f32>> = {
        let mut out = Vec::with_capacity(scale.query_batch);
        let mut row = vec![0f32; data.dim()];
        for i in 0..scale.query_batch {
            data.write_row_dense(i * 7 % data.n(), &mut row);
            out.push(row.clone());
        }
        out
    };
    let listener = TcpListener::bind("127.0.0.1:0").expect("bind loopback");
    let addr = listener.local_addr().unwrap();
    let served = session::OnlineSession::from_data(data.clone(), cfg(scale.k))
        .expect("session");
    let reg = Arc::new(ModelRegistry::with_default(served));
    let server = std::thread::spawn(move || {
        nmbkm::serve::server::serve_listener(reg, listener).unwrap();
    });

    // training pressure: keep stepping until the clients are done
    let stop = Arc::new(AtomicBool::new(false));
    let trainer_stop = stop.clone();
    let trainer = std::thread::spawn(move || {
        let (mut conn, mut reader) = connect(addr);
        while !trainer_stop.load(Ordering::SeqCst) {
            let resp = roundtrip(
                &mut conn,
                &mut reader,
                r#"{"op":"step","rounds":1}"#,
            );
            assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
        }
        (conn, reader)
    });

    let req = format!("{{\"op\":\"predict\",\"points\":{}}}", points_json(&queries));
    let per_conn = scale.predicts_per_conn;
    let mut clients = Vec::new();
    for _ in 0..conns {
        let req = req.clone();
        clients.push(std::thread::spawn(move || {
            let (mut conn, mut reader) = connect(addr);
            for _ in 0..per_conn {
                let resp = roundtrip(&mut conn, &mut reader, &req);
                assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(true));
            }
        }));
    }
    for c in clients {
        c.join().unwrap();
    }
    stop.store(true, Ordering::SeqCst);
    let (mut conn, mut reader) = trainer.join().unwrap();
    roundtrip(&mut conn, &mut reader, r#"{"op":"shutdown"}"#);
    server.join().unwrap();
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = BenchOpts::from_env_or_args(&args);
    let json_path = args
        .iter()
        .position(|a| a == "--json")
        .and_then(|p| args.get(p + 1).cloned());
    let scale = scale_for(&opts);
    let data = GaussianMixture::default_spec(scale.k, scale.dim)
        .generate(scale.n_points, 7);

    let mut report = BenchReport::new("serve_throughput");
    report.meta("threads", json::num(Pool::auto().threads as f64));
    report.meta("n_points", json::num(scale.n_points as f64));
    report.meta("k", json::num(scale.k as f64));
    report.meta("dim", json::num(scale.dim as f64));
    report.meta(
        "predicts_per_conn",
        json::num(scale.predicts_per_conn as f64),
    );

    let mut set = BenchSet::new("predict_during_training", opts);
    for conns in [1usize, 4] {
        set.bench(&format!("conns{conns}"), || {
            run_trial(&data, &scale, conns)
        });
    }
    // derived: aggregate QPS at each arity, and the scaling ratio the
    // reader/writer split buys (4 conns do 4x the work; perfect scaling
    // keeps wall time flat → ratio ≈ 4)
    let t1 = set.get("conns1").map(|m| m.median_secs()).unwrap_or(f64::NAN);
    let t4 = set.get("conns4").map(|m| m.median_secs()).unwrap_or(f64::NAN);
    let total1 = scale.predicts_per_conn as f64;
    let total4 = 4.0 * scale.predicts_per_conn as f64;
    report.meta("qps_conns1", json::num(total1 / t1));
    report.meta("qps_conns4", json::num(total4 / t4));
    report.meta("scaling_x", json::num((total4 / t4) / (total1 / t1)));
    println!(
        "predict throughput during training: {:.0} qps @1 conn, {:.0} qps @4 conns ({:.2}x)",
        total1 / t1,
        total4 / t4,
        (total4 / t4) / (total1 / t1)
    );
    report.push(set);
    if let Some(path) = json_path {
        report.write(&path).expect("writing bench report");
    }
}
