//! Row-major dense matrices and the dense distance kernels.
//!
//! The assignment hot loop uses the norms decomposition
//! `‖x−c‖² = ‖x‖² + ‖c‖² − 2⟨x,c⟩` so the inner loop is a pure dot
//! product — the same form the L1 Pallas kernel uses on the MXU — with
//! an 8-way unrolled accumulator that the compiler autovectorises.

/// Row-major `rows × cols` matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl DenseMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// ‖row_i‖² for every row.
    pub fn row_sq_norms(&self) -> Vec<f32> {
        (0..self.rows).map(|i| sq_norm(self.row(i))).collect()
    }

    /// Materialise a row permutation: `out.row(i) = self.row(perm[i])`.
    pub fn permute_rows(&self, perm: &[usize]) -> DenseMatrix {
        assert_eq!(perm.len(), self.rows);
        let mut out = DenseMatrix::zeros(self.rows, self.cols);
        for (i, &p) in perm.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(p));
        }
        out
    }

    /// Rows `[lo, hi)` as a new matrix.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> DenseMatrix {
        assert!(lo <= hi && hi <= self.rows);
        DenseMatrix::from_vec(
            hi - lo,
            self.cols,
            self.data[lo * self.cols..hi * self.cols].to_vec(),
        )
    }
}

/// Dot product, 8-way unrolled. The central FLOP sink of the native
/// engine; see benches/micro_hotpaths.
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let n = a.len();
    let chunks = n / 8;
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    let (mut s4, mut s5, mut s6, mut s7) = (0f32, 0f32, 0f32, 0f32);
    for c in 0..chunks {
        let i = c * 8;
        // Safety: i+7 < chunks*8 <= n, same for b.
        unsafe {
            s0 += a.get_unchecked(i) * b.get_unchecked(i);
            s1 += a.get_unchecked(i + 1) * b.get_unchecked(i + 1);
            s2 += a.get_unchecked(i + 2) * b.get_unchecked(i + 2);
            s3 += a.get_unchecked(i + 3) * b.get_unchecked(i + 3);
            s4 += a.get_unchecked(i + 4) * b.get_unchecked(i + 4);
            s5 += a.get_unchecked(i + 5) * b.get_unchecked(i + 5);
            s6 += a.get_unchecked(i + 6) * b.get_unchecked(i + 6);
            s7 += a.get_unchecked(i + 7) * b.get_unchecked(i + 7);
        }
    }
    let mut tail = 0f32;
    for i in chunks * 8..n {
        tail += a[i] * b[i];
    }
    ((s0 + s1) + (s2 + s3)) + ((s4 + s5) + (s6 + s7)) + tail
}

/// ‖a‖².
#[inline]
pub fn sq_norm(a: &[f32]) -> f32 {
    dot(a, a)
}

/// Exact squared distance (no norms trick; used by oracles and tests).
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// Squared distance via the norms decomposition (hot-path form; can be
/// slightly negative from cancellation, clamped to 0).
#[inline]
pub fn sq_dist_norms(x: &[f32], xn: f32, c: &[f32], cn: f32) -> f32 {
    (xn + cn - 2.0 * dot(x, c)).max(0.0)
}

/// Four dot products against consecutive centroid rows sharing one
/// streaming pass over `x` — register blocking that quarters x-loads
/// and widens ILP (EXPERIMENTS.md §Perf change 4).
#[inline]
fn dot4(x: &[f32], c0: &[f32], c1: &[f32], c2: &[f32], c3: &[f32]) -> [f32; 4] {
    let n = x.len();
    let (mut s0, mut s1, mut s2, mut s3) = (0f32, 0f32, 0f32, 0f32);
    let (mut t0, mut t1, mut t2, mut t3) = (0f32, 0f32, 0f32, 0f32);
    let chunks = n / 2;
    for ci in 0..chunks {
        let i = ci * 2;
        // Safety: i+1 < chunks*2 <= n for all five slices (same length).
        unsafe {
            let xa = *x.get_unchecked(i);
            let xb = *x.get_unchecked(i + 1);
            s0 += xa * c0.get_unchecked(i);
            t0 += xb * c0.get_unchecked(i + 1);
            s1 += xa * c1.get_unchecked(i);
            t1 += xb * c1.get_unchecked(i + 1);
            s2 += xa * c2.get_unchecked(i);
            t2 += xb * c2.get_unchecked(i + 1);
            s3 += xa * c3.get_unchecked(i);
            t3 += xb * c3.get_unchecked(i + 1);
        }
    }
    if n % 2 == 1 {
        let i = n - 1;
        s0 += x[i] * c0[i];
        s1 += x[i] * c1[i];
        s2 += x[i] * c2[i];
        s3 += x[i] * c3[i];
    }
    [s0 + t0, s1 + t1, s2 + t2, s3 + t3]
}

/// Nearest centroid of `x` among the rows of `c` (norms trick).
/// Returns `(argmin_j, min_j ‖x−c_j‖²)` — the native counterpart of the
/// L1 `assign` kernel. Processes centroids in blocks of four so the
/// point vector is streamed once per block instead of once per centroid.
#[inline]
pub fn nearest(x: &[f32], xn: f32, c: &DenseMatrix, cnorms: &[f32]) -> (u32, f32) {
    debug_assert_eq!(c.rows, cnorms.len());
    let mut best_j = 0u32;
    let mut best = f32::INFINITY;
    let k = c.rows;
    let blocks = k / 4;
    for b in 0..blocks {
        let j = b * 4;
        let dots = dot4(x, c.row(j), c.row(j + 1), c.row(j + 2), c.row(j + 3));
        for (o, &dt) in dots.iter().enumerate() {
            let d2 = (xn + cnorms[j + o] - 2.0 * dt).max(0.0);
            if d2 < best {
                best = d2;
                best_j = (j + o) as u32;
            }
        }
    }
    for j in blocks * 4..k {
        let d2 = sq_dist_norms(x, xn, c.row(j), cnorms[j]);
        if d2 < best {
            best = d2;
            best_j = j as u32;
        }
    }
    (best_j, best)
}

/// `acc += x` with f64 accumulation (sufficient-statistics path).
#[inline]
pub fn add_into(acc: &mut [f64], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for i in 0..x.len() {
        acc[i] += x[i] as f64;
    }
}

/// `acc -= x` with f64 accumulation.
#[inline]
pub fn sub_from(acc: &mut [f64], x: &[f32]) {
    debug_assert_eq!(acc.len(), x.len());
    for i in 0..x.len() {
        acc[i] -= x[i] as f64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{gen, Cases};

    #[test]
    fn dot_matches_naive() {
        Cases::new(100).run(|rng| {
            let n = rng.below(200);
            let a = gen::matrix(rng, 1, n);
            let b = gen::matrix(rng, 1, n);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let got = dot(&a, &b);
            assert!(
                (got - naive).abs() <= 1e-3 * (1.0 + naive.abs()),
                "n={n} got={got} naive={naive}"
            );
        });
    }

    #[test]
    fn sq_dist_norms_matches_exact() {
        Cases::new(100).run(|rng| {
            let d = rng.below(100) + 1;
            let a = gen::matrix(rng, 1, d);
            let b = gen::matrix(rng, 1, d);
            let exact = sq_dist(&a, &b);
            let via = sq_dist_norms(&a, sq_norm(&a), &b, sq_norm(&b));
            assert!(
                (exact - via).abs() <= 1e-2 * (1.0 + exact.abs()),
                "d={d} exact={exact} via={via}"
            );
        });
    }

    #[test]
    fn nearest_matches_bruteforce() {
        Cases::new(60).run(|rng| {
            let (_, d, k) = gen::shape(rng, 1, 50, 12);
            let c = DenseMatrix::from_vec(k, d, gen::matrix(rng, k, d));
            let cn = c.row_sq_norms();
            let x = gen::matrix(rng, 1, d);
            let xn = sq_norm(&x);
            let (j, d2) = nearest(&x, xn, &c, &cn);
            let brute: Vec<f32> =
                (0..k).map(|j| sq_dist(&x, c.row(j))).collect();
            let jb = brute
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            // allow tie-or-epsilon disagreement on the index, but the
            // achieved distance must be ≈ optimal
            assert!(
                (d2 - brute[jb]).abs() <= 1e-2 * (1.0 + brute[jb].abs()),
                "d2={d2} best={} j={j} jb={jb}",
                brute[jb]
            );
        });
    }

    #[test]
    fn permute_and_slice() {
        let m = DenseMatrix::from_vec(3, 2, vec![0., 1., 10., 11., 20., 21.]);
        let p = m.permute_rows(&[2, 0, 1]);
        assert_eq!(p.row(0), &[20., 21.]);
        assert_eq!(p.row(1), &[0., 1.]);
        let s = p.slice_rows(1, 3);
        assert_eq!(s.rows, 2);
        assert_eq!(s.row(1), &[10., 11.]);
    }

    #[test]
    fn add_sub_roundtrip() {
        let mut acc = vec![1.0f64; 5];
        let x: Vec<f32> = vec![0.5; 5];
        add_into(&mut acc, &x);
        sub_from(&mut acc, &x);
        for v in acc {
            assert!((v - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_shape() {
        DenseMatrix::from_vec(2, 2, vec![0.0; 3]);
    }
}
