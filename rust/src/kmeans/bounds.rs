//! Triangle-inequality lower bounds (Elkan 2003) for the turbocharged
//! algorithms.
//!
//! [`BoundStore`] keeps `l(i,j) ≤ ‖x_i − c_j‖` for every *active* point
//! (the nested batch prefix). Two consumption modes:
//!
//! * [`tb_point_step`] — the paper's Algorithm 9/11 inner loop verbatim:
//!   recompute d(i) exactly, decay each bound by `p(j)`, recompute a
//!   distance only when the bound fails. This is the native engine path.
//! * [`screen`] / tile refresh — the hardware-adapted path (DESIGN.md
//!   §Hardware-Adaptation): a cheap O(k) vector screen flags *dirty*
//!   points, which the coordinator gathers into dense tiles for the
//!   XLA/Pallas `distmat` artifact; clean points skip the O(dk) work
//!   entirely. Assignments produced by both paths are identical.
//!
//! Validity invariant (tested): after any sequence of operations,
//! `l(i,j) ≤ ‖x_i − c_j‖` for all active i, j.

use crate::data::Data;
use crate::kmeans::state::Centroids;
use crate::linalg::neighbours::{self, probe_stride, NeighbourIndex};

/// Dense per-point × per-centroid lower-bound matrix for the active
/// batch; rows are appended as the nested batch grows (M_t ⊆ M_{t+1}
/// means a row, once created, stays).
#[derive(Clone, Debug)]
pub struct BoundStore {
    pub k: usize,
    pub n: usize,
    lb: Vec<f32>,
}

impl BoundStore {
    pub fn new(k: usize) -> Self {
        Self { k, n: 0, lb: vec![] }
    }

    /// Extend to `n` active points (new rows zeroed: 0 is always a valid
    /// lower bound; they are tightened at the point's first full assign).
    pub fn grow_to(&mut self, n: usize) {
        assert!(n >= self.n, "nested batches never shrink");
        self.lb.resize(n * self.k, 0.0);
        self.n = n;
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.lb[i * self.k..(i + 1) * self.k]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.lb[i * self.k..(i + 1) * self.k]
    }

    /// Split the store into disjoint per-chunk mutable row views
    /// matching `ranges` (for lock-free sharded mutation).
    pub fn split_rows<'a>(
        &'a mut self,
        ranges: &[std::ops::Range<usize>],
    ) -> Vec<&'a mut [f32]> {
        let k = self.k;
        let mut out = Vec::with_capacity(ranges.len());
        let mut rest: &mut [f32] = &mut self.lb;
        let mut consumed = 0;
        for r in ranges {
            debug_assert_eq!(r.start, consumed);
            let (head, tail) = rest.split_at_mut(r.len() * k);
            out.push(head);
            rest = tail;
            consumed += r.len();
        }
        out
    }
}

/// Result of one bounded reassignment step for a point.
#[derive(Clone, Copy, Debug)]
pub struct StepOut {
    pub label: u32,
    /// exact ‖x_i − c_label‖² after the step
    pub d2: f32,
    pub dist_calcs: u64,
    pub bound_skips: u64,
}

/// Algorithm 9/11 lines 10–22 for one already-seen point: exact distance
/// to the current centroid, then bound-gated scans of the others.
/// `lb_row` is this point's k bounds (mutated in place).
#[inline]
pub fn tb_point_step(
    data: &Data,
    i: usize,
    cent: &Centroids,
    lb_row: &mut [f32],
    a_old: u32,
) -> StepOut {
    let k = cent.k();
    debug_assert_eq!(lb_row.len(), k);
    let ao = a_old as usize;
    // d(i) ← ‖x(i) − c(a_o)‖  (always exact: 1 distance calc)
    let mut d2 = data.sq_dist_to(i, cent.c.row(ao), cent.norms[ao]);
    let mut d = d2.sqrt();
    lb_row[ao] = d;
    let mut a = a_old;
    let mut calcs = 1u64;
    let mut skips = 0u64;
    for j in 0..k {
        if j == ao {
            continue;
        }
        // l(i,j) ← l(i,j) − p(j)
        let mut l = lb_row[j] - cent.p[j];
        if l < d {
            // bound failed: recompute exactly
            let dj2 = data.sq_dist_to(i, cent.c.row(j), cent.norms[j]);
            let dj = dj2.sqrt();
            l = dj;
            calcs += 1;
            if dj < d {
                d = dj;
                d2 = dj2;
                a = j as u32;
            }
        } else {
            skips += 1;
        }
        lb_row[j] = l;
    }
    StepOut { label: a, d2, dist_calcs: calcs, bound_skips: skips }
}

/// First full assignment of a new point (Alg. 9 lines 33–36): compute
/// all k distances, install them as exact bounds, return the argmin.
#[inline]
pub fn full_assign_fill(
    data: &Data,
    i: usize,
    cent: &Centroids,
    lb_row: &mut [f32],
) -> StepOut {
    let k = cent.k();
    let mut best = f32::INFINITY;
    let mut best_j = 0u32;
    for j in 0..k {
        let dj2 = data.sq_dist_to(i, cent.c.row(j), cent.norms[j]);
        let dj = dj2.sqrt();
        lb_row[j] = dj;
        if dj2 < best {
            best = dj2;
            best_j = j as u32;
        }
    }
    StepOut { label: best_j, d2: best, dist_calcs: k as u64, bound_skips: 0 }
}

/// [`full_assign_fill`] with exponion pruning: same bit-identical label
/// and d² (strided probes seed the ball, the sorted neighbour row cuts
/// the walk, out-of-order ties resolved by the explicit `j < best_j`
/// rule — the same argument as `neighbours::nearest_dense_exponion`),
/// but centroids outside the ball get the certified *ring* lower bound
/// `max(cc_lo(s,j) − r_s, 0)` instead of an exact distance. Every
/// installed bound satisfies `lb ≤ ‖x_i − c_j‖`, so the Elkan/tb bound
/// machinery downstream is untouched; only `dist_calcs` shrinks.
pub fn full_assign_fill_pruned(
    data: &Data,
    i: usize,
    cent: &Centroids,
    ni: &NeighbourIndex,
    lb_row: &mut [f32],
) -> StepOut {
    let k = cent.k();
    debug_assert_eq!(ni.k(), k);
    debug_assert_eq!(ni.d(), cent.d());
    debug_assert_eq!(lb_row.len(), k);
    let xn = data.norms[i];
    let stride = probe_stride(k);
    let mut best = f32::INFINITY;
    let mut best_j = 0u32;
    let mut calcs = 0u64;
    let mut j = 0usize;
    while j < k {
        let dj2 = data.sq_dist_to(i, cent.c.row(j), cent.norms[j]);
        lb_row[j] = dj2.sqrt();
        calcs += 1;
        if dj2 < best {
            best = dj2;
            best_j = j as u32;
        }
        j += stride;
    }
    let seed = best_j as usize;
    let slack = ni.slack_term(neighbours::slack_dense(cent.d()), xn);
    let r_s = ((best as f64) + slack).sqrt() * 1.000_000_1;
    let dec = ni.decay[seed];
    let mut thr = r_s + ((best as f64) + slack).sqrt() * 1.000_000_1;
    let (ccs, idxs) = ni.rows.row(seed);
    let mut p = 0usize;
    while p < ccs.len() {
        let cc_adj = ccs[p] as f64 - dec;
        if cc_adj > thr {
            break;
        }
        let jj = idxs[p] as usize;
        p += 1;
        if jj % stride == 0 {
            continue; // probed: exact bound already installed
        }
        let dj2 = data.sq_dist_to(i, cent.c.row(jj), cent.norms[jj]);
        lb_row[jj] = dj2.sqrt();
        calcs += 1;
        if dj2 < best || (dj2 == best && (jj as u32) < best_j) {
            best = dj2;
            best_j = jj as u32;
            thr = r_s + ((best as f64) + slack).sqrt() * 1.000_000_1;
        }
    }
    // beyond the ring: install the certified ring bound for everything
    // not already computed (probed slots keep their exact value)
    let mut skips = 0u64;
    while p < ccs.len() {
        let jj = idxs[p] as usize;
        p += 1;
        if jj % stride == 0 {
            continue;
        }
        let lo = (ccs[p - 1] as f64 - dec - r_s).max(0.0) * 0.999_999;
        lb_row[jj] = lo as f32;
        skips += 1;
    }
    StepOut { label: best_j, d2: best, dist_calcs: calcs, bound_skips: skips }
}

/// The tile-path screen: decay this row's bounds by `p`, and report
/// whether the point is *dirty* — some non-assigned centroid's bound
/// dipped below the point's (decayed) upper bound `u`.
///
/// `u` must satisfy `u ≥ ‖x_i − c_{a}‖` (maintained by the caller as
/// `u ← u + p(a)` between rounds). Clean ⇒ the assignment provably
/// cannot change, so the point skips the distance tile.
#[inline]
pub fn screen(lb_row: &mut [f32], p: &[f32], a: u32, u: f32) -> bool {
    let mut dirty = false;
    for j in 0..lb_row.len() {
        let l = lb_row[j] - p[j];
        lb_row[j] = l;
        if j as u32 != a && l < u {
            dirty = true;
        }
    }
    dirty
}

/// Tile-path refresh after the `distmat` artifact returned the full
/// distance row for a dirty point: install exact bounds, return argmin.
#[inline]
pub fn refresh_from_distrow(lb_row: &mut [f32], dist2_row: &[f32]) -> (u32, f32) {
    debug_assert_eq!(lb_row.len(), dist2_row.len());
    let mut best = f32::INFINITY;
    let mut best_j = 0u32;
    for j in 0..lb_row.len() {
        let d2 = dist2_row[j].max(0.0);
        lb_row[j] = d2.sqrt();
        if d2 < best {
            best = d2;
            best_j = j as u32;
        }
    }
    (best_j, best)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian::GaussianMixture;
    use crate::kmeans::init;
    use crate::util::propcheck::Cases;

    fn exact_dist(data: &Data, i: usize, cent: &Centroids, j: usize) -> f32 {
        data.sq_dist_to(i, cent.c.row(j), cent.norms[j]).sqrt()
    }

    #[test]
    fn full_assign_installs_exact_bounds() {
        let data = GaussianMixture::default_spec(4, 6).generate(30, 1);
        let cent = init::first_k(&data, 4);
        let mut store = BoundStore::new(4);
        store.grow_to(30);
        for i in 0..30 {
            let out = full_assign_fill(&data, i, &cent, store.row_mut(i));
            let (j_ref, d2_ref) = data.nearest(i, &cent.c, &cent.norms);
            // nearest() uses the 4-way blocked dot (different summation
            // order than the per-centroid path) — compare with an fp
            // tolerance, and allow index disagreement only on ties
            assert!(
                (out.d2 - d2_ref).abs() <= 1e-4 * (1.0 + d2_ref),
                "i={i}: {} vs {d2_ref}",
                out.d2
            );
            if out.label != j_ref {
                let alt = data.sq_dist_to(
                    i,
                    cent.c.row(out.label as usize),
                    cent.norms[out.label as usize],
                );
                assert!((alt - d2_ref).abs() <= 1e-4 * (1.0 + d2_ref));
            }
            for j in 0..4 {
                let e = exact_dist(&data, i, &cent, j);
                assert!((store.row(i)[j] - e).abs() < 1e-4 * (1.0 + e));
            }
        }
    }

    #[test]
    fn bounds_remain_valid_under_centroid_motion() {
        // property: after decay + step, l(i,j) ≤ ‖x_i − c_j‖ always
        Cases::new(20).run(|rng| {
            let k = 2 + rng.below(6);
            let n = 20 + rng.below(40);
            let data = GaussianMixture::default_spec(k, 5)
                .generate(n, rng.next_u64());
            let mut cent = init::first_k(&data, k);
            let mut store = BoundStore::new(k);
            store.grow_to(n);
            let mut labels = vec![0u32; n];
            for i in 0..n {
                labels[i] =
                    full_assign_fill(&data, i, &cent, store.row_mut(i)).label;
            }
            for _round in 0..3 {
                // jitter centroids, record p(j) = true displacement
                for j in 0..k {
                    let mut disp2 = 0f64;
                    for t in 0..cent.d() {
                        let delta = rng.gauss_f32() * 0.3;
                        cent.c.row_mut(j)[t] += delta;
                        disp2 += (delta as f64) * (delta as f64);
                    }
                    cent.p[j] = (disp2 as f64).sqrt() as f32;
                }
                for j in 0..k {
                    cent.norms[j] =
                        crate::linalg::dense::sq_norm(cent.c.row(j));
                }
                for i in 0..n {
                    let out = tb_point_step(
                        &data,
                        i,
                        &cent,
                        store.row_mut(i),
                        labels[i],
                    );
                    labels[i] = out.label;
                    // validity of every bound
                    for j in 0..k {
                        let e = exact_dist(&data, i, &cent, j);
                        assert!(
                            store.row(i)[j] <= e + 1e-3 * (1.0 + e),
                            "bound {} > exact {e}",
                            store.row(i)[j]
                        );
                    }
                    // assignment must equal brute force
                    let (j_ref, d2_ref) =
                        data.nearest(i, &cent.c, &cent.norms);
                    assert!(
                        (out.d2 - d2_ref).abs() <= 1e-4 * (1.0 + d2_ref),
                        "tb step d2 {} vs exact {d2_ref}",
                        out.d2
                    );
                    let _ = j_ref; // index may differ only on exact ties
                }
            }
        });
    }

    #[test]
    fn stationary_centroids_skip_everything() {
        let data = GaussianMixture::default_spec(5, 8).generate(50, 3);
        let cent = init::first_k(&data, 5); // p = 0
        let mut store = BoundStore::new(5);
        store.grow_to(50);
        let mut labels = vec![0u32; 50];
        for i in 0..50 {
            labels[i] =
                full_assign_fill(&data, i, &cent, store.row_mut(i)).label;
        }
        // second pass with p = 0: every non-assigned bound must hold
        let mut total_calcs = 0;
        let mut total_skips = 0;
        for i in 0..50 {
            let out =
                tb_point_step(&data, i, &cent, store.row_mut(i), labels[i]);
            assert_eq!(out.label, labels[i]);
            total_calcs += out.dist_calcs;
            total_skips += out.bound_skips;
        }
        // exactly 1 calc per point (the d(i) recompute), rest skipped
        assert_eq!(total_calcs, 50);
        assert_eq!(total_skips, 50 * 4);
    }

    #[test]
    fn screen_matches_tb_step_dirtiness() {
        // A clean verdict from `screen` must imply tb_point_step keeps
        // the assignment.
        Cases::new(20).run(|rng| {
            let k = 2 + rng.below(5);
            let data = GaussianMixture::default_spec(k, 4)
                .generate(30, rng.next_u64());
            let mut cent = init::first_k(&data, k);
            let mut store = BoundStore::new(k);
            store.grow_to(30);
            let mut labels = vec![0u32; 30];
            let mut upper = vec![0f32; 30];
            for i in 0..30 {
                let out = full_assign_fill(&data, i, &cent, store.row_mut(i));
                labels[i] = out.label;
                upper[i] = out.d2.sqrt();
            }
            // small centroid jitter
            for j in 0..k {
                let mut disp2 = 0f64;
                for t in 0..cent.d() {
                    let delta = rng.gauss_f32() * 0.05;
                    cent.c.row_mut(j)[t] += delta;
                    disp2 += (delta as f64) * (delta as f64);
                }
                cent.p[j] = (disp2 as f64).sqrt() as f32;
                cent.norms[j] = crate::linalg::dense::sq_norm(cent.c.row(j));
            }
            for i in 0..30 {
                let mut row_copy = store.row(i).to_vec();
                let u = upper[i] + cent.p[labels[i] as usize];
                let dirty = screen(&mut row_copy, &cent.p, labels[i], u);
                let out = tb_point_step(
                    &data,
                    i,
                    &cent,
                    store.row_mut(i),
                    labels[i],
                );
                if !dirty {
                    assert_eq!(
                        out.label, labels[i],
                        "clean point changed assignment"
                    );
                }
                labels[i] = out.label;
                upper[i] = out.d2.sqrt();
            }
        });
    }

    #[test]
    fn pruned_fill_matches_full_fill_and_bounds_stay_valid() {
        // exponion-pruned first fills: label/d² bit-identical to the
        // exhaustive fill, every installed bound (exact or ring) valid,
        // and strictly fewer distance computations — across centroid
        // motion so warm (synced/decayed) structures are exercised too
        use crate::linalg::neighbours::NeighbourCache;
        use crate::linalg::simd;
        if simd::tier() == simd::Tier::Avx2Fma {
            return; // the opt-in FMA tier is documented as unfaithful
        }
        Cases::new(6).run(|rng| {
            let k = 24 + rng.below(40);
            let n = k + 20;
            let data = GaussianMixture::default_spec(k, 6)
                .generate(n, rng.next_u64());
            let mut cent = init::first_k(&data, k);
            let cache = NeighbourCache::default();
            let mut skips_total = 0u64;
            for _round in 0..2 {
                let ni = cache.get(&cent, simd::tier());
                for i in 0..n {
                    let mut full = vec![0f32; k];
                    let mut pruned = vec![0f32; k];
                    let a = full_assign_fill(&data, i, &cent, &mut full);
                    let b = full_assign_fill_pruned(
                        &data, i, &cent, &ni, &mut pruned,
                    );
                    assert_eq!(b.label, a.label, "i={i}");
                    assert_eq!(b.d2.to_bits(), a.d2.to_bits(), "i={i}");
                    assert!(b.dist_calcs + b.bound_skips == k as u64);
                    skips_total += b.bound_skips;
                    for j in 0..k {
                        let e = exact_dist(&data, i, &cent, j);
                        assert!(
                            pruned[j] <= e + 1e-3 * (1.0 + e),
                            "i={i} j={j}: ring bound {} > exact {e}",
                            pruned[j]
                        );
                    }
                }
                // drift the centroids (bumping rev) so round 2 runs on
                // a synced-or-rebuilt neighbour structure
                for j in 0..k {
                    for t in 0..cent.d() {
                        cent.c.row_mut(j)[t] += rng.gauss_f32() * 0.01;
                    }
                    cent.norms[j] =
                        crate::linalg::dense::sq_norm(cent.c.row(j));
                }
                cent.touch();
            }
            assert!(
                skips_total > 0,
                "exponion never pruned at k={k} — gate or bounds broken"
            );
        });
    }

    #[test]
    fn refresh_from_distrow_sets_exact() {
        let mut lb = vec![0f32; 3];
        let (j, d2) = refresh_from_distrow(&mut lb, &[4.0, 1.0, 9.0]);
        assert_eq!(j, 1);
        assert_eq!(d2, 1.0);
        assert_eq!(lb, vec![2.0, 1.0, 3.0]);
    }

    #[test]
    fn split_rows_disjoint() {
        let mut store = BoundStore::new(3);
        store.grow_to(10);
        let ranges = crate::coordinator::shard::chunk_ranges(10, 3, 1);
        let views = store.split_rows(&ranges);
        let total: usize = views.iter().map(|v| v.len()).sum();
        assert_eq!(total, 30);
    }

    #[test]
    #[should_panic]
    fn never_shrinks() {
        let mut store = BoundStore::new(2);
        store.grow_to(5);
        store.grow_to(3);
    }
}
