//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments
//! and subcommands, with typed getters and an auto-generated usage
//! string. All experiment binaries and the main CLI build on this.

use std::collections::BTreeMap;

/// Declarative option spec used for usage text and validation.
#[derive(Clone, Debug)]
pub struct OptSpec {
    pub name: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
    pub help: &'static str,
}

/// Parsed arguments.
#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    values: BTreeMap<String, String>,
    flags: Vec<String>,
}

#[derive(Debug)]
pub struct ArgError(pub String);

impl std::fmt::Display for ArgError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "argument error: {}", self.0)
    }
}

impl std::error::Error for ArgError {}

impl Args {
    /// Parse raw argv (without the program name) against a spec.
    pub fn parse(raw: &[String], spec: &[OptSpec]) -> Result<Args, ArgError> {
        let mut args = Args::default();
        // seed defaults
        for s in spec {
            if let Some(d) = s.default {
                args.values.insert(s.name.to_string(), d.to_string());
            }
        }
        let mut it = raw.iter().peekable();
        while let Some(tok) = it.next() {
            if let Some(body) = tok.strip_prefix("--") {
                let (name, inline_val) = match body.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (body.to_string(), None),
                };
                let sp = spec
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| ArgError(format!("unknown option --{name}")))?;
                if sp.takes_value {
                    let v = match inline_val {
                        Some(v) => v,
                        None => it
                            .next()
                            .cloned()
                            .ok_or_else(|| ArgError(format!("--{name} needs a value")))?,
                    };
                    args.values.insert(name, v);
                } else {
                    if inline_val.is_some() {
                        return Err(ArgError(format!("--{name} takes no value")));
                    }
                    args.flags.push(name);
                }
            } else {
                args.positional.push(tok.clone());
            }
        }
        Ok(args)
    }

    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str) -> Result<usize, ArgError> {
        self.req(name)?
            .parse()
            .map_err(|_| ArgError(format!("--{name} must be an integer")))
    }

    pub fn get_u64(&self, name: &str) -> Result<u64, ArgError> {
        self.req(name)?
            .parse()
            .map_err(|_| ArgError(format!("--{name} must be an integer")))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64, ArgError> {
        let v = self.req(name)?;
        if v == "inf" || v == "infinity" {
            return Ok(f64::INFINITY);
        }
        v.parse()
            .map_err(|_| ArgError(format!("--{name} must be a number")))
    }

    fn req(&self, name: &str) -> Result<&str, ArgError> {
        self.get(name)
            .ok_or_else(|| ArgError(format!("missing required --{name}")))
    }
}

/// Render a usage block from a spec.
pub fn usage(prog: &str, about: &str, spec: &[OptSpec]) -> String {
    let mut out = format!("{prog} — {about}\n\noptions:\n");
    for s in spec {
        let head = if s.takes_value {
            format!("  --{} <v>", s.name)
        } else {
            format!("  --{}", s.name)
        };
        let def = s
            .default
            .map(|d| format!(" [default: {d}]"))
            .unwrap_or_default();
        out.push_str(&format!("{head:<24}{}{def}\n", s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec() -> Vec<OptSpec> {
        vec![
            OptSpec { name: "k", takes_value: true, default: Some("50"), help: "clusters" },
            OptSpec { name: "rho", takes_value: true, default: None, help: "threshold" },
            OptSpec { name: "quick", takes_value: false, default: None, help: "small run" },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = Args::parse(&sv(&[]), &spec()).unwrap();
        assert_eq!(a.get_usize("k").unwrap(), 50);
        let a = Args::parse(&sv(&["--k", "8"]), &spec()).unwrap();
        assert_eq!(a.get_usize("k").unwrap(), 8);
        let a = Args::parse(&sv(&["--k=9"]), &spec()).unwrap();
        assert_eq!(a.get_usize("k").unwrap(), 9);
    }

    #[test]
    fn flags_and_positionals() {
        let a = Args::parse(&sv(&["fig1", "--quick", "x"]), &spec()).unwrap();
        assert!(a.flag("quick"));
        assert_eq!(a.positional, vec!["fig1", "x"]);
    }

    #[test]
    fn rho_inf() {
        let a = Args::parse(&sv(&["--rho", "inf"]), &spec()).unwrap();
        assert!(a.get_f64("rho").unwrap().is_infinite());
        let a = Args::parse(&sv(&["--rho", "100"]), &spec()).unwrap();
        assert_eq!(a.get_f64("rho").unwrap(), 100.0);
    }

    #[test]
    fn errors() {
        assert!(Args::parse(&sv(&["--bogus"]), &spec()).is_err());
        assert!(Args::parse(&sv(&["--rho"]), &spec()).is_err());
        assert!(Args::parse(&sv(&["--quick=1"]), &spec()).is_err());
        let a = Args::parse(&sv(&["--k", "x"]), &spec()).unwrap();
        assert!(a.get_usize("k").is_err());
    }

    #[test]
    fn usage_contains_options() {
        let u = usage("nmbkm", "test", &spec());
        assert!(u.contains("--k"));
        assert!(u.contains("default: 50"));
    }
}
