//! Shared clustering state: centroids and sufficient statistics.
//!
//! The nested-batch algorithms' correctness hinges on *exact* maintenance
//! of `S(j) = Σ_{i: a(i)=j} x_i` and `v(j) = |{i: a(i)=j}|` under
//! millions of add/remove cycles, so the accumulators are `f64` while
//! data and centroids stay `f32` (the integration tests check S/v
//! against from-scratch recomputation).
//!
//! `sse(j)` follows the paper's Algorithm 7 bookkeeping *faithfully*,
//! including its deliberate staleness: when a point's assignment is
//! unchanged the add/subtract cancels, so its contribution keeps the
//! distance from the round it last moved. The controller only needs the
//! magnitude of σ̂_C, and this is exactly what the paper computes.

use crate::coordinator::merge::Mergeable;
use crate::data::Data;
use crate::linalg::dense::DenseMatrix;
#[cfg(test)]
use crate::linalg::dense;

/// Sentinel for "never assigned".
pub const UNASSIGNED: u32 = u32::MAX;

/// Globally unique revision stamps for [`Centroids`] content. Monotonic
/// across all instances, so a revision value identifies one centroid
/// snapshot for the lifetime of the process. The per-engine transpose
/// caches key on it — process-uniqueness is what lets every session
/// keep its own cache handle without any cross-session coordination
/// (two sessions can never mint the same revision for different
/// content).
static CENTROID_REV: std::sync::atomic::AtomicU64 =
    std::sync::atomic::AtomicU64::new(1);

fn next_rev() -> u64 {
    CENTROID_REV.fetch_add(1, std::sync::atomic::Ordering::Relaxed)
}

/// Centroids with the cached quantities the hot paths need.
#[derive(Clone, Debug)]
pub struct Centroids {
    /// k × d row-major centroid matrix.
    pub c: DenseMatrix,
    /// ‖c_j‖² (norms-trick distances).
    pub norms: Vec<f32>,
    /// p(j): distance moved in the most recent update (Elkan decay).
    pub p: Vec<f32>,
    /// Content revision: process-unique stamp refreshed by [`touch`]
    /// whenever `c` changes. Derived caches (the engine's transposed
    /// centroid block) key on it, so any code mutating `c` outside
    /// [`SuffStats::update_centroids`] must call `touch()` before the
    /// centroids reach an engine again. Clones share the revision
    /// (identical content).
    ///
    /// [`touch`]: Centroids::touch
    pub rev: u64,
}

impl Centroids {
    pub fn from_matrix(c: DenseMatrix) -> Self {
        let norms = c.row_sq_norms();
        let k = c.rows;
        Self { c, norms, p: vec![0.0; k], rev: next_rev() }
    }

    /// Rehydrate from serialised parts (snapshot load). `norms` and `p`
    /// are restored verbatim rather than recomputed: `update_centroids`
    /// refreshes norms through an f64 accumulator whose rounding differs
    /// from `row_sq_norms`, and bit-exact resume requires the exact
    /// values the paused run held.
    pub fn from_parts(c: DenseMatrix, norms: Vec<f32>, p: Vec<f32>) -> Self {
        assert_eq!(norms.len(), c.rows, "norms length != k");
        assert_eq!(p.len(), c.rows, "p length != k");
        Self { c, norms, p, rev: next_rev() }
    }

    /// Mark the centroid content as changed (fresh process-unique
    /// revision), invalidating revision-keyed caches.
    pub fn touch(&mut self) {
        self.rev = next_rev();
    }

    pub fn k(&self) -> usize {
        self.c.rows
    }

    pub fn d(&self) -> usize {
        self.c.cols
    }

    /// Max displacement in the last update (0 ⇒ fixed point).
    pub fn max_p(&self) -> f32 {
        self.p.iter().cloned().fold(0.0, f32::max)
    }
}

/// Sufficient statistics `(S, v, sse)` per cluster. Also used as the
/// *delta* type produced by worker shards (same shape, merged by `+`).
#[derive(Clone, Debug)]
pub struct SuffStats {
    pub k: usize,
    pub d: usize,
    /// k × d flattened f64 coordinate sums.
    pub s: Vec<f64>,
    /// assignment counts (f64: merged/compared with paper formulas).
    pub v: Vec<f64>,
    /// per-cluster Σ d(i)² bookkeeping (Alg. 7 lines 14–15).
    pub sse: Vec<f64>,
}

impl SuffStats {
    pub fn zeros(k: usize, d: usize) -> Self {
        Self { k, d, s: vec![0.0; k * d], v: vec![0.0; k], sse: vec![0.0; k] }
    }

    /// Rehydrate from serialised parts (snapshot load).
    pub fn from_parts(
        k: usize,
        d: usize,
        s: Vec<f64>,
        v: Vec<f64>,
        sse: Vec<f64>,
    ) -> Self {
        assert_eq!(s.len(), k * d, "S length != k*d");
        assert_eq!(v.len(), k, "v length != k");
        assert_eq!(sse.len(), k, "sse length != k");
        Self { k, d, s, v, sse }
    }

    #[inline]
    pub fn s_row(&self, j: usize) -> &[f64] {
        &self.s[j * self.d..(j + 1) * self.d]
    }

    #[inline]
    pub fn s_row_mut(&mut self, j: usize) -> &mut [f64] {
        &mut self.s[j * self.d..(j + 1) * self.d]
    }

    /// Add point `i` to cluster `j` (first assignment).
    #[inline]
    pub fn add_point(&mut self, data: &Data, i: usize, j: u32, d2: f32) {
        let j = j as usize;
        data.add_row_to(i, &mut self.s[j * self.d..(j + 1) * self.d]);
        self.v[j] += 1.0;
        self.sse[j] += d2 as f64;
    }

    /// Remove point `i` from cluster `j` (mb-f decontamination). The
    /// `d2` passed is whatever bookkeeping value was added for it.
    #[inline]
    pub fn remove_point(&mut self, data: &Data, i: usize, j: u32, d2: f32) {
        let j = j as usize;
        data.sub_row_from(i, &mut self.s[j * self.d..(j + 1) * self.d]);
        self.v[j] -= 1.0;
        self.sse[j] -= d2 as f64;
    }

    /// Alg. 7 lines 14–21: always move the sse contribution by the *new*
    /// d², and move S/v only when the assignment actually changed.
    #[inline]
    pub fn reassign_point(
        &mut self,
        data: &Data,
        i: usize,
        from: u32,
        to: u32,
        d2_new: f32,
    ) {
        let (fj, tj) = (from as usize, to as usize);
        self.sse[fj] -= d2_new as f64;
        self.sse[tj] += d2_new as f64;
        if from != to {
            data.sub_row_from(i, &mut self.s[fj * self.d..(fj + 1) * self.d]);
            data.add_row_to(i, &mut self.s[tj * self.d..(tj + 1) * self.d]);
            self.v[fj] -= 1.0;
            self.v[tj] += 1.0;
        }
    }

    /// The paper's σ̂_C(j) = sqrt(sse(j) / (v(j)(v(j)−1))); ∞ when the
    /// cluster has fewer than two points (no variance estimate → always
    /// votes to grow).
    pub fn sigma_c(&self, j: usize) -> f64 {
        let v = self.v[j];
        if v < 2.0 {
            return f64::INFINITY;
        }
        (self.sse[j].max(0.0) / (v * (v - 1.0))).sqrt()
    }

    /// Write `C(j) ← S(j)/v(j)` into `centroids`, computing displacement
    /// `p(j)` and refreshing norms. Clusters with `v = 0` keep their old
    /// centroid (p = 0).
    pub fn update_centroids(&self, centroids: &mut Centroids) {
        debug_assert_eq!(centroids.k(), self.k);
        debug_assert_eq!(centroids.d(), self.d);
        for j in 0..self.k {
            if self.v[j] <= 0.0 {
                centroids.p[j] = 0.0;
                continue;
            }
            let inv = 1.0 / self.v[j];
            let row = centroids.c.row_mut(j);
            let mut disp2 = 0f64;
            let mut norm = 0f64;
            let s = &self.s[j * self.d..(j + 1) * self.d];
            for t in 0..self.d {
                let new = (s[t] * inv) as f32;
                let diff = (new - row[t]) as f64;
                disp2 += diff * diff;
                norm += (new as f64) * (new as f64);
                row[t] = new;
            }
            centroids.p[j] = (disp2 as f32).sqrt();
            centroids.norms[j] = norm as f32;
        }
        centroids.touch();
    }

    /// Recompute from scratch for a set of assigned points (tests and
    /// lloyd's non-incremental path).
    pub fn rebuild(
        data: &Data,
        k: usize,
        idx: impl Iterator<Item = usize>,
        assign: &[u32],
        dist2: &[f32],
    ) -> SuffStats {
        let mut st = SuffStats::zeros(k, data.dim());
        for i in idx {
            debug_assert_ne!(assign[i], UNASSIGNED);
            st.add_point(data, i, assign[i], dist2[i]);
        }
        st
    }

    /// Max |difference| against another stats object (test helper).
    pub fn max_abs_diff(&self, other: &SuffStats) -> f64 {
        let ds = self
            .s
            .iter()
            .zip(&other.s)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        let dv = self
            .v
            .iter()
            .zip(&other.v)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max);
        ds.max(dv)
    }
}

impl Mergeable for SuffStats {
    fn merge(&mut self, other: Self) {
        debug_assert_eq!(self.k, other.k);
        debug_assert_eq!(self.d, other.d);
        for (a, b) in self.s.iter_mut().zip(&other.s) {
            *a += b;
        }
        for (a, b) in self.v.iter_mut().zip(&other.v) {
            *a += b;
        }
        for (a, b) in self.sse.iter_mut().zip(&other.sse) {
            *a += b;
        }
    }
}

/// Per-point assignment state shared by the incremental algorithms.
#[derive(Clone, Debug)]
pub struct Assignments {
    /// a(i); UNASSIGNED until first use.
    pub label: Vec<u32>,
    /// d(i)² as last computed for point i.
    pub dist2: Vec<f32>,
}

impl Assignments {
    pub fn new(n: usize) -> Self {
        Self { label: vec![UNASSIGNED; n], dist2: vec![f32::INFINITY; n] }
    }

    /// Rehydrate from serialised parts (snapshot load).
    pub fn from_parts(label: Vec<u32>, dist2: Vec<f32>) -> Self {
        assert_eq!(label.len(), dist2.len(), "label/dist2 length mismatch");
        Self { label, dist2 }
    }

    pub fn seen(&self, i: usize) -> bool {
        self.label[i] != UNASSIGNED
    }
}

/// Training-set MSE for the currently assigned prefix (Σ d²/count) —
/// a free byproduct of the stats, used for progress logs.
pub fn batch_mse(stats: &SuffStats) -> f64 {
    let n: f64 = stats.v.iter().sum();
    if n <= 0.0 {
        return f64::NAN;
    }
    stats.sse.iter().sum::<f64>().max(0.0) / n
}

/// Exact MSE of `data` under `centroids` computed fresh (O(nkd)); the
/// metrics path uses the engine-parallel version, this is the oracle.
pub fn exact_mse(data: &Data, centroids: &Centroids) -> f64 {
    let mut total = 0f64;
    for i in 0..data.n() {
        let (_, d2) = data.nearest(i, &centroids.c, &centroids.norms);
        total += d2 as f64;
    }
    total / data.n() as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian::GaussianMixture;
    use crate::util::propcheck::Cases;

    fn toy() -> (Data, Centroids) {
        let data = GaussianMixture::default_spec(3, 4).generate(20, 1);
        let mut c = DenseMatrix::zeros(3, 4);
        for j in 0..3 {
            let mut row = vec![0.0; 4];
            data.write_row_dense(j, &mut row);
            c.row_mut(j).copy_from_slice(&row);
        }
        (data, Centroids::from_matrix(c))
    }

    #[test]
    fn add_remove_roundtrip_exact() {
        let (data, _) = toy();
        let mut st = SuffStats::zeros(3, 4);
        for i in 0..10 {
            st.add_point(&data, i, (i % 3) as u32, 1.0);
        }
        for i in 0..10 {
            st.remove_point(&data, i, (i % 3) as u32, 1.0);
        }
        assert!(st.s.iter().all(|&x| x.abs() < 1e-9));
        assert!(st.v.iter().all(|&x| x.abs() < 1e-12));
        assert!(st.sse.iter().all(|&x| x.abs() < 1e-9));
    }

    #[test]
    fn reassign_moves_s_and_v() {
        let (data, _) = toy();
        let mut st = SuffStats::zeros(3, 4);
        st.add_point(&data, 0, 0, 2.0);
        st.reassign_point(&data, 0, 0, 1, 0.5);
        assert_eq!(st.v[0], 0.0);
        assert_eq!(st.v[1], 1.0);
        let mut row = vec![0f32; 4];
        data.write_row_dense(0, &mut row);
        for t in 0..4 {
            assert!((st.s_row(1)[t] - row[t] as f64).abs() < 1e-9);
            assert!(st.s_row(0)[t].abs() < 1e-9);
        }
        // unchanged reassignment is an sse no-op
        let before = st.sse.clone();
        st.reassign_point(&data, 0, 1, 1, 7.0);
        assert_eq!(st.sse, before);
        assert_eq!(st.v[1], 1.0);
    }

    #[test]
    fn update_centroids_is_mean_and_p_correct() {
        let (data, mut cent) = toy();
        let mut st = SuffStats::zeros(3, 4);
        // assign points 0..6 to cluster 1
        for i in 0..6 {
            st.add_point(&data, i, 1, 0.0);
        }
        let old = cent.c.row(1).to_vec();
        st.update_centroids(&mut cent);
        // cluster 1 is the mean of the 6 points
        let mut mean = vec![0f64; 4];
        for i in 0..6 {
            data.add_row_to(i, &mut mean);
        }
        for t in 0..4 {
            assert!((cent.c.row(1)[t] as f64 - mean[t] / 6.0).abs() < 1e-5);
        }
        // p(1) = ‖new − old‖
        let p_expect = dense::sq_dist(&old, cent.c.row(1)).sqrt();
        assert!((cent.p[1] - p_expect).abs() < 1e-4);
        // empty clusters unchanged with p = 0
        assert_eq!(cent.p[0], 0.0);
        // norms refreshed
        assert!(
            (cent.norms[1] - dense::sq_norm(cent.c.row(1))).abs()
                < 1e-3 * (1.0 + cent.norms[1].abs())
        );
    }

    #[test]
    fn sigma_c_formula() {
        let mut st = SuffStats::zeros(2, 1);
        st.v[0] = 5.0;
        st.sse[0] = 20.0;
        assert!((st.sigma_c(0) - (20.0 / 20.0f64).sqrt()).abs() < 1e-12);
        st.v[1] = 1.0;
        assert!(st.sigma_c(1).is_infinite());
    }

    #[test]
    fn merge_is_sum() {
        let mut a = SuffStats::zeros(2, 2);
        let mut b = SuffStats::zeros(2, 2);
        a.v[0] = 1.0;
        b.v[0] = 2.0;
        a.s[3] = 4.0;
        b.s[3] = 6.0;
        a.merge(b);
        assert_eq!(a.v[0], 3.0);
        assert_eq!(a.s[3], 10.0);
    }

    #[test]
    fn rebuild_matches_incremental() {
        Cases::new(20).run(|rng| {
            let n = 30 + rng.below(50);
            let k = 2 + rng.below(5);
            let data =
                GaussianMixture::default_spec(k, 6).generate(n, rng.next_u64());
            let mut st = SuffStats::zeros(k, 6);
            let mut assign = vec![UNASSIGNED; n];
            let mut dist2 = vec![0f32; n];
            for i in 0..n {
                let j = rng.below(k) as u32;
                assign[i] = j;
                dist2[i] = rng.next_f32();
                st.add_point(&data, i, j, dist2[i]);
            }
            // random churn
            for _ in 0..n {
                let i = rng.below(n);
                let to = rng.below(k) as u32;
                let d2 = rng.next_f32();
                st.reassign_point(&data, i, assign[i], to, d2);
                assign[i] = to;
                if true {
                    dist2[i] = d2;
                }
            }
            let fresh = SuffStats::rebuild(&data, k, 0..n, &assign, &dist2);
            assert!(
                st.max_abs_diff(&fresh) < 1e-6,
                "S/v drifted: {}",
                st.max_abs_diff(&fresh)
            );
        });
    }

    #[test]
    fn exact_mse_zero_when_centroids_are_points() {
        let data = GaussianMixture::default_spec(2, 3).generate(2, 0);
        let mut c = DenseMatrix::zeros(2, 3);
        let mut row = vec![0.0; 3];
        for j in 0..2 {
            data.write_row_dense(j, &mut row);
            c.row_mut(j).copy_from_slice(&row);
        }
        let cent = Centroids::from_matrix(c);
        assert!(exact_mse(&data, &cent) < 1e-6);
    }
}
