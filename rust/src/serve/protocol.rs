//! The serving wire protocol: JSON Lines, dependency-free, transport
//! agnostic (stdio and TCP both speak it — see `serve::server`).
//!
//! One request per line, one response per line, in order. Requests are
//! routed to a named model in the [`ModelRegistry`]; omitting the
//! `model` field routes to the implicit `default` model, so PR 1's
//! single-model clients keep working unchanged:
//!
//! ```text
//! → {"op":"create","model":"news","k":20,"dim":64,"algo":"tb"}
//! ← {"ok":true,"op":"create","model":"news","k":20,"dim":64}
//! → {"op":"ingest","model":"news","points":[[…],[…]],"rounds":2}
//! ← {"ok":true,"op":"ingest","model":"news","added":2,"n":10002,…}
//! → {"op":"predict","model":"news","points":[[…]]}
//! ← {"ok":true,"op":"predict","model":"news","labels":[7],"d2":[0.125]}
//! → {"op":"list"}
//! ← {"ok":true,"op":"list","models":[{"model":"news",…},…]}
//! → {"op":"stats"}                     (routes to "default")
//! ← {"ok":true,"op":"stats","model":"default","initialised":true,…}
//! → {"op":"snapshot","model":"news","path":"news.json"}
//! ← {"ok":true,"op":"snapshot","model":"news","path":"…","bytes":123}
//! → {"op":"drop","model":"news"}
//! ← {"ok":true,"op":"drop","model":"news"}
//! → {"op":"shutdown"}
//! ← {"ok":true,"op":"shutdown"}
//! ```
//!
//! Everywhere a request carries points (`ingest`, `predict`), each row
//! is either a dense JSON array **or** the sparse form
//! `{"indices":[…],"values":[…],"dim":d}` (strictly ascending indices;
//! encodings may mix within one request). Sparse rows decode straight
//! into the CSR storage the engine consumes — no densify round-trip —
//! and score bit-identically to their dense twins (`serve::wire`,
//! enforced by `tests/serve_wire.rs`):
//!
//! ```text
//! → {"op":"predict","points":[{"indices":[3,17],"values":[0.5,1.25],"dim":47236}]}
//! ← {"ok":true,"op":"predict","model":"default","labels":[7],"d2":[0.125]}
//! ```
//!
//! Mutations (`ingest`/`step`/`snapshot`) serialise on their model's
//! session lock; `predict` runs lock-free against the model's published
//! snapshot — large `points` arrays are additionally split across the
//! model's shard pool, one published-`Arc` clone per sub-batch (see
//! `serve::registry`) — so concurrent connections' predicts proceed
//! while a round trains. Errors never kill the stream: a malformed or
//! failing request gets `{"ok":false,"error":"…"}` and the loop
//! continues. `d2` values are exact — f32 widens losslessly to the f64
//! JSON number and the parser round-trips f64, so predict responses
//! carry the same bits the engine produced. (The opt-in binary framing
//! in `serve::frame` carries the same ops with raw f32 payloads.)

use crate::config::{Algo, Rho, RunConfig};
use crate::obs;
use crate::serve::frame;
use crate::serve::observe;
use crate::serve::registry::ModelRegistry;
use crate::serve::wal;
use crate::serve::wire::{self, WireRow};
use crate::util::json::{self, Json};
use anyhow::{anyhow, bail, ensure, Result};
use std::io::{BufRead, Write};

/// A parsed request. `model: None` routes to the implicit default.
#[derive(Clone, Debug, PartialEq)]
pub enum Request {
    /// Register a fresh empty model (initialises once k points arrive).
    Create { model: Option<String>, dim: usize, cfg: RunConfig },
    /// Published summaries of every model.
    List,
    /// Remove a model (explicit name required — no implicit default).
    Drop { model: String },
    /// Append points, then (optionally) run training rounds over the
    /// grown buffer.
    Ingest {
        model: Option<String>,
        points: Vec<WireRow>,
        rounds: usize,
        seconds: f64,
    },
    /// Nearest-centroid queries (lock-free, snapshot-isolated).
    /// `binary: true` asks for the response as a magic-prefixed binary
    /// frame even on a JSONL connection (bulk answers skip float
    /// formatting without committing the whole connection to framing).
    Predict { model: Option<String>, points: Vec<WireRow>, binary: bool },
    /// Run training rounds without new data.
    Step { model: Option<String>, rounds: usize, seconds: f64 },
    /// Observability counters.
    Stats { model: Option<String> },
    /// Scrape the whole metrics registry (per-model op counters and
    /// latency histograms, kernel counters, SIMD dispatch tally,
    /// transpose-cache stats) as the stable `{"schema":1,"metrics":[…]}`
    /// document — the same sample set the Prometheus endpoint serves.
    Metrics,
    /// Persist the model (and, unless `include_data` is false, the
    /// buffer) to a snapshot file on the server's filesystem.
    Snapshot { model: Option<String>, path: String, include_data: bool },
    /// Replication handshake: WAL epoch, next/oldest retained seq, and
    /// each model's last applied seq (requires `--wal-dir`).
    SyncInfo,
    /// Raw WAL records from `from` onward (binary framing only — the
    /// response body is the on-disk record bytes).
    WalFetch { from: u64, max: usize },
    /// Stream one model's full snapshot with its last applied seq, for
    /// follower bootstrap (binary framing only).
    SyncSnapshot { model: Option<String> },
    /// Promote a follower: bump the WAL epoch (fencing the old primary)
    /// and start accepting mutations.
    Promote,
    /// Stop serving (closes every connection; the TCP server exits its
    /// accept loop).
    Shutdown,
}

/// Parse one request line.
pub fn parse_request(line: &str) -> Result<Request> {
    let v = Json::parse(line).map_err(|e| anyhow!("bad request json: {e}"))?;
    request_from_json(&v, None)
}

/// Build a request from an already-parsed JSON object, optionally with
/// points decoded out-of-band (the binary framing carries them as raw
/// f32 blocks next to the JSON header). `points: Some(…)` takes
/// precedence over a `points` field in `v`.
pub fn request_from_json(
    v: &Json,
    mut points: Option<Vec<WireRow>>,
) -> Result<Request> {
    let mut take_points = || -> Result<Vec<WireRow>> {
        match points.take() {
            Some(p) => Ok(p),
            None => wire::rows_from_json(v),
        }
    };
    let op = v
        .get("op")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow!("request missing string field 'op'"))?;
    let model = || -> Result<Option<String>> {
        match v.get("model") {
            None => Ok(None),
            Some(x) => x
                .as_str()
                .map(|s| Some(s.to_string()))
                .ok_or_else(|| anyhow!("'model' must be a string")),
        }
    };
    let rounds = |default: usize| -> Result<usize> {
        match v.get("rounds") {
            None => Ok(default),
            Some(x) => x
                .as_f64()
                .filter(|r| *r >= 0.0 && r.fract() == 0.0)
                .map(|r| r as usize)
                .ok_or_else(|| anyhow!("'rounds' must be a non-negative integer")),
        }
    };
    let seconds = || -> Result<f64> {
        match v.get("seconds") {
            None => Ok(f64::INFINITY),
            Some(x) => x
                .as_f64()
                .filter(|s| *s >= 0.0)
                .ok_or_else(|| anyhow!("'seconds' must be a non-negative number")),
        }
    };
    Ok(match op {
        "create" => {
            let (dim, cfg) = parse_create(v)?;
            Request::Create { model: model()?, dim, cfg }
        }
        "list" => Request::List,
        "drop" => Request::Drop {
            model: v
                .get("model")
                .and_then(Json::as_str)
                .ok_or_else(|| {
                    anyhow!("drop op needs an explicit 'model' string")
                })?
                .to_string(),
        },
        "ingest" => Request::Ingest {
            model: model()?,
            points: take_points()?,
            rounds: rounds(1)?,
            seconds: seconds()?,
        },
        "predict" => Request::Predict {
            model: model()?,
            points: take_points()?,
            binary: v.get("binary").and_then(Json::as_bool).unwrap_or(false),
        },
        "step" => Request::Step {
            model: model()?,
            rounds: rounds(1)?,
            seconds: seconds()?,
        },
        "stats" => Request::Stats { model: model()? },
        "metrics" => Request::Metrics,
        "snapshot" => Request::Snapshot {
            model: model()?,
            path: v
                .get("path")
                .and_then(Json::as_str)
                .ok_or_else(|| anyhow!("snapshot op needs a 'path' string"))?
                .to_string(),
            include_data: v
                .get("include_data")
                .and_then(Json::as_bool)
                .unwrap_or(true),
        },
        "sync-info" => Request::SyncInfo,
        "wal-fetch" => Request::WalFetch {
            from: wal::u64_field(v, "from")
                .map_err(|e| anyhow!("wal-fetch: {e:#}"))?,
            max: match v.get("max") {
                None => wal::DEFAULT_FETCH_BYTES,
                Some(x) => x
                    .as_f64()
                    .filter(|m| *m >= 1.0 && m.fract() == 0.0)
                    .map(|m| (m as usize).min(wal::MAX_FETCH_BYTES))
                    .ok_or_else(|| anyhow!("'max' must be a positive integer"))?,
            },
        },
        "sync-snapshot" => Request::SyncSnapshot { model: model()? },
        "promote" => Request::Promote,
        "shutdown" | "quit" => Request::Shutdown,
        other => bail!(
            "unknown op '{other}' (create|list|drop|ingest|predict|step|\
             stats|snapshot|metrics|sync-info|wal-fetch|sync-snapshot|\
             promote|shutdown)"
        ),
    })
}

/// `create` parameters: required `k` and `dim`, optional `algo`, `b0`,
/// `rho`, `seed`, `threads` on top of serving defaults.
fn parse_create(v: &Json) -> Result<(usize, RunConfig)> {
    let req_usize = |key: &str| -> Result<usize> {
        v.get(key)
            .and_then(Json::as_f64)
            .filter(|x| *x >= 1.0 && x.fract() == 0.0)
            .map(|x| x as usize)
            .ok_or_else(|| {
                anyhow!("create op needs a positive integer '{key}'")
            })
    };
    let opt_usize = |key: &str| -> Result<Option<usize>> {
        match v.get(key) {
            None => Ok(None),
            Some(x) => x
                .as_f64()
                .filter(|x| *x >= 1.0 && x.fract() == 0.0)
                .map(|x| Some(x as usize))
                .ok_or_else(|| anyhow!("'{key}' must be a positive integer")),
        }
    };
    let dim = req_usize("dim")?;
    let mut cfg = RunConfig {
        k: req_usize("k")?,
        // serving sessions run under step/ingest budgets, not a global
        // clock, so the per-call limits are the real control surface
        max_seconds: f64::INFINITY,
        max_rounds: usize::MAX,
        ..RunConfig::default()
    };
    if let Some(x) = v.get("algo") {
        let s = x.as_str().ok_or_else(|| anyhow!("'algo' must be a string"))?;
        cfg.algo = Algo::parse(s).map_err(|e| anyhow!("{e}"))?;
    }
    if let Some(x) = v.get("rho") {
        let s = x.as_str().ok_or_else(|| anyhow!("'rho' must be a string"))?;
        cfg.rho = Rho::parse(s).map_err(|e| anyhow!("{e}"))?;
    }
    if let Some(b0) = opt_usize("b0")? {
        cfg.b0 = b0;
    }
    if let Some(threads) = opt_usize("threads")? {
        // remote clients must not get a spawn-arbitrary-OS-threads
        // primitive (same posture as the snapshot op's path confinement);
        // clamp to the host's parallelism
        let host = std::thread::available_parallelism()
            .map(|x| x.get())
            .unwrap_or(1);
        cfg.threads = threads.min(host);
    }
    if let Some(x) = v.get("seed") {
        let seed = x
            .as_f64()
            .filter(|s| *s >= 0.0 && s.fract() == 0.0)
            .ok_or_else(|| anyhow!("'seed' must be a non-negative integer"))?;
        cfg.seed = seed as u64;
    }
    Ok((dim, cfg))
}

/// Execute one request against the registry. Never fails: errors become
/// `ok:false` responses. The bool is true when the server should stop.
pub fn handle_line(registry: &ModelRegistry, line: &str) -> (Json, bool) {
    let req = match parse_request(line) {
        Ok(r) => r,
        Err(e) => {
            observe::serve_metrics().op_counter("invalid").inc();
            return (err_json(&e), false);
        }
    };
    handle_request(registry, &req)
}

/// Execute an already-parsed request: the shared core of the JSONL and
/// binary-frame transports. Never fails; the bool asks the server to
/// stop. Every request lands in `nmbkm_requests_total{op=…}` and the
/// `nmbkm_request_seconds` histogram here, whichever transport carried
/// it.
pub fn handle_request(registry: &ModelRegistry, req: &Request) -> (Json, bool) {
    let m = observe::serve_metrics();
    m.op_counter(observe::op_name(req)).inc();
    let timer = obs::Timer::start();
    let out = match execute(registry, req) {
        Ok(resp) => (resp, matches!(req, Request::Shutdown)),
        Err(e) => (err_json(&e), false),
    };
    timer.observe(&m.request_seconds);
    // mutations may have grown the log past the checkpoint threshold;
    // the checkpoint runs here, outside every session lock, and a
    // failure never poisons the response (the log alone still recovers)
    if matches!(
        req,
        Request::Create { .. }
            | Request::Ingest { .. }
            | Request::Step { .. }
            | Request::Drop { .. }
    ) {
        if let Some(w) = registry.wal() {
            if let Err(e) = w.maybe_checkpoint(registry) {
                eprintln!("[nmbkm::wal] checkpoint failed: {e:#}");
            }
        }
    }
    out
}

pub(crate) fn err_json(e: &anyhow::Error) -> Json {
    let msg = format!("{e:#}");
    observe::serve_metrics().errors.inc();
    obs::log::event("error", &[("message", json::s(&msg))]);
    json::obj(vec![("ok", Json::Bool(false)), ("error", json::s(&msg))])
}

fn execute(registry: &ModelRegistry, req: &Request) -> Result<Json> {
    // a follower's state is a bit-exact mirror of its primary's log —
    // local mutations would fork it, so they are refused outright
    if registry.is_follower()
        && matches!(
            req,
            Request::Create { .. }
                | Request::Ingest { .. }
                | Request::Step { .. }
                | Request::Drop { .. }
        )
    {
        bail!(
            "read-only follower — this node tails a primary's log \
             (send 'promote' to make it writable)"
        );
    }
    Ok(match req {
        Request::Create { model, dim, cfg } => {
            let name = model.as_deref().unwrap_or(crate::serve::registry::DEFAULT_MODEL);
            let entry = registry.create(name, cfg.clone(), *dim)?;
            json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", json::s("create")),
                ("model", json::s(entry.name())),
                ("k", json::num(cfg.k as f64)),
                ("dim", json::num(*dim as f64)),
                ("algo", json::s(&cfg.label())),
            ])
        }
        Request::List => json::obj(vec![
            ("ok", Json::Bool(true)),
            ("op", json::s("list")),
            (
                "models",
                Json::Arr(
                    registry.list().iter().map(|m| m.summary_json()).collect(),
                ),
            ),
        ]),
        Request::Drop { model } => {
            registry.drop_model(model)?;
            json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", json::s("drop")),
                ("model", json::s(model)),
            ])
        }
        Request::Ingest { model, points, rounds, seconds } => {
            let entry = registry.resolve(model.as_deref())?;
            let w = registry.wal();
            let timer = obs::Timer::start();
            let (n, rep, initialised) = entry.with_session_mut(|s| {
                let was_init = s.initialised();
                let n = s.ingest_wire(points)?;
                let rep = s.step(*rounds, *seconds)?;
                // logged inside the session lock with the *actual*
                // effect (rounds really run), so log order is mutation
                // order and a time-budgeted call replays exactly;
                // pure no-ops (nothing added, nothing ran, no init
                // flip) stay out of the log
                if let Some(w) = &w {
                    if !points.is_empty()
                        || rep.rounds_run > 0
                        || s.initialised() != was_init
                    {
                        let header = json::obj(vec![
                            ("op", json::s("ingest")),
                            ("model", json::s(entry.name())),
                            ("rounds", json::num(rep.rounds_run as f64)),
                        ]);
                        let seq =
                            w.append(&header, &wire::encode_rows(points))?;
                        entry.set_last_seq(seq);
                    }
                }
                Ok((n, rep, s.initialised()))
            })?;
            let mm = entry.metrics();
            mm.ingest_requests.inc();
            mm.ingest_points.add(points.len() as u64);
            timer.observe(&mm.ingest_seconds);
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("op", json::s("ingest")),
                ("model", json::s(entry.name())),
                ("added", json::num(points.len() as f64)),
                ("n", json::num(n as f64)),
                ("rounds_run", json::num(rep.rounds_run as f64)),
                ("initialised", Json::Bool(initialised)),
            ];
            if let Some(info) = rep.last {
                fields.push(("batch", json::num(info.batch as f64)));
                fields.push(("train_mse", json::num(info.train_mse)));
            }
            json::obj(fields)
        }
        Request::Predict { model, points, .. } => {
            let entry = registry.resolve(model.as_deref())?;
            // lock-free: computed against the published snapshot, even
            // while a training step holds the session lock; large
            // batches split across the model's shard pool
            let (lbl, d2) = entry.predict_wire(points)?;
            json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", json::s("predict")),
                ("model", json::s(entry.name())),
                (
                    "labels",
                    Json::Arr(lbl.iter().map(|&j| json::num(j as f64)).collect()),
                ),
                (
                    "d2",
                    Json::Arr(d2.iter().map(|&x| json::num(x as f64)).collect()),
                ),
            ])
        }
        Request::Step { model, rounds, seconds } => {
            let entry = registry.resolve(model.as_deref())?;
            let w = registry.wal();
            let timer = obs::Timer::start();
            let rep = entry.with_session_mut(|s| {
                let was_init = s.initialised();
                let rep = s.step(*rounds, *seconds)?;
                if let Some(w) = &w {
                    if rep.rounds_run > 0 || s.initialised() != was_init {
                        let header = json::obj(vec![
                            ("op", json::s("step")),
                            ("model", json::s(entry.name())),
                            ("rounds", json::num(rep.rounds_run as f64)),
                        ]);
                        let seq = w.append(&header, &[])?;
                        entry.set_last_seq(seq);
                    }
                }
                Ok(rep)
            })?;
            let mm = entry.metrics();
            mm.step_requests.inc();
            mm.step_rounds.add(rep.rounds_run as u64);
            timer.observe(&mm.step_seconds);
            let mut fields = vec![
                ("ok", Json::Bool(true)),
                ("op", json::s("step")),
                ("model", json::s(entry.name())),
                ("rounds_run", json::num(rep.rounds_run as f64)),
                ("converged", Json::Bool(rep.converged)),
                ("waiting_for_points", Json::Bool(rep.waiting_for_points)),
            ];
            if let Some(info) = rep.last {
                fields.push(("batch", json::num(info.batch as f64)));
                fields.push(("train_mse", json::num(info.train_mse)));
            }
            json::obj(fields)
        }
        Request::Stats { model } => {
            let entry = registry.resolve(model.as_deref())?;
            let mut resp = entry.with_session(|s| Ok(s.stats_json()))?;
            if let Json::Obj(m) = &mut resp {
                m.insert("ok".to_string(), Json::Bool(true));
                m.insert("op".to_string(), json::s("stats"));
                m.insert("model".to_string(), json::s(entry.name()));
            }
            resp
        }
        Request::Snapshot { model, path, include_data } => {
            // clients name a bare file inside the server's snapshot
            // directory; anything path-like is rejected so a remote peer
            // never gets an arbitrary-file-write primitive
            ensure!(
                !path.is_empty()
                    && path != "."
                    && path != ".."
                    && !path.contains('/')
                    && !path.contains('\\')
                    // ':' blocks Windows drive-prefixed names like
                    // "C:evil", which Path::join resolves outside the base
                    && !path.contains(':')
                    && !path.contains('\0'),
                "snapshot 'path' must be a bare file name (it is resolved \
                 inside the server's snapshot directory), got {path:?}"
            );
            let entry = registry.resolve(model.as_deref())?;
            let fmt = registry.snapshot_format();
            let target = entry.with_session(|s| {
                let target = s.snapshot_dir().join(path);
                // streams from borrowed state — no data-buffer clone
                s.save_snapshot_as(&target, *include_data, fmt)?;
                Ok(target)
            })?;
            let bytes = std::fs::metadata(&target).map(|m| m.len()).unwrap_or(0);
            json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", json::s("snapshot")),
                ("model", json::s(entry.name())),
                ("path", json::s(&target.display().to_string())),
                ("bytes", json::num(bytes as f64)),
            ])
        }
        Request::Metrics => {
            let mut resp = observe::metrics_json(registry);
            if let Json::Obj(m) = &mut resp {
                m.insert("ok".to_string(), Json::Bool(true));
                m.insert("op".to_string(), json::s("metrics"));
            }
            resp
        }
        Request::SyncInfo => {
            let w = registry.wal().ok_or_else(|| {
                anyhow!("no wal attached — start the server with --wal-dir")
            })?;
            json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", json::s("sync-info")),
                ("epoch", wal::u64_json(w.epoch())),
                ("next", wal::u64_json(w.next_seq())),
                ("oldest", wal::u64_json(w.oldest_retained()?)),
                ("follower", Json::Bool(registry.is_follower())),
                ("models", registry.sync_rows()),
            ])
        }
        // these two ship binary bodies (raw log records / a snapshot
        // stream); serve::frame intercepts them before this point
        Request::WalFetch { .. } => bail!(
            "wal-fetch requires the binary framing (serve --binary)"
        ),
        Request::SyncSnapshot { .. } => bail!(
            "sync-snapshot requires the binary framing (serve --binary)"
        ),
        Request::Promote => {
            ensure!(
                registry.is_follower(),
                "already primary — nothing to promote"
            );
            let w = registry.wal().ok_or_else(|| {
                anyhow!("no wal attached — start the server with --wal-dir")
            })?;
            // epoch first, then writability: by the time a mutation can
            // land here, stale-primary batches are already fenced out
            let epoch = w.bump_epoch()?;
            registry.set_follower(false);
            obs::log::event(
                "promote",
                &[("epoch", wal::u64_json(epoch))],
            );
            json::obj(vec![
                ("ok", Json::Bool(true)),
                ("op", json::s("promote")),
                ("epoch", wal::u64_json(epoch)),
            ])
        }
        Request::Shutdown => json::obj(vec![
            ("ok", Json::Bool(true)),
            ("op", json::s("shutdown")),
        ]),
    })
}

/// A JSONL request's encoded answer: a JSON line, or — for predicts
/// carrying the `"binary":true` response hint — a magic-prefixed binary
/// frame (the client re-enters text mode after reading it, since frames
/// are length-delimited).
pub enum LineReply {
    Json(Json),
    Frame(Vec<u8>),
}

/// Execute one parsed JSONL request. The `"binary":true` predict hint
/// takes the frame fast path and answers `MAGIC + frame` when it
/// succeeds; its errors (and every other op) stay JSON, so a client can
/// always classify the answer by its first byte (`{` vs [`frame::MAGIC`]).
pub fn execute_line(registry: &ModelRegistry, req: &Request) -> (LineReply, bool) {
    if let Request::Predict { model, points, binary: true } = req {
        let (h, body, quit) =
            frame::predict_response(registry, model.as_deref(), points);
        if h.get("ok").and_then(Json::as_bool) == Some(true) {
            let mut buf = vec![frame::MAGIC];
            // writing into a Vec cannot fail
            let _ = frame::write_frame(&mut buf, &h, &body);
            return (LineReply::Frame(buf), quit);
        }
        return (LineReply::Json(h), quit);
    }
    let (resp, quit) = handle_request(registry, req);
    (LineReply::Json(resp), quit)
}

/// Drive a whole request stream: read JSONL requests from `input`, write
/// JSONL responses to `output`. Returns true when the stream ended with
/// an explicit shutdown (as opposed to EOF).
pub fn serve_lines<R: BufRead, W: Write>(
    registry: &ModelRegistry,
    input: R,
    output: &mut W,
) -> Result<bool> {
    let m = observe::serve_metrics();
    for line in input.lines() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        m.jsonl_bytes_read.add(line.len() as u64 + 1);
        let (reply, quit) = match parse_request(&line) {
            Ok(req) => execute_line(registry, &req),
            Err(e) => {
                m.op_counter("invalid").inc();
                (LineReply::Json(err_json(&e)), false)
            }
        };
        match reply {
            LineReply::Json(resp) => {
                let resp = resp.to_string();
                writeln!(output, "{resp}")?;
                output.flush()?;
                m.jsonl_bytes_written.add(resp.len() as u64 + 1);
            }
            LineReply::Frame(bytes) => {
                output.write_all(&bytes)?;
                output.flush()?;
                m.jsonl_bytes_written.add(bytes.len() as u64);
            }
        }
        if quit {
            return Ok(true);
        }
    }
    Ok(false)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Algo, Rho, RunConfig};
    use crate::data::gaussian::GaussianMixture;
    use crate::serve::session;

    fn ready_registry() -> ModelRegistry {
        let data = GaussianMixture::default_spec(3, 4).generate(300, 1);
        let cfg = RunConfig {
            algo: Algo::GbRho,
            k: 3,
            b0: 32,
            rho: Rho::Infinite,
            threads: 2,
            max_rounds: 5,
            max_seconds: 30.0,
            ..Default::default()
        };
        ModelRegistry::with_default(session::train(&data, &cfg).unwrap().0)
    }

    #[test]
    fn parse_request_forms() {
        assert_eq!(
            parse_request(r#"{"op":"stats"}"#).unwrap(),
            Request::Stats { model: None }
        );
        assert_eq!(
            parse_request(r#"{"op":"stats","model":"m1"}"#).unwrap(),
            Request::Stats { model: Some("m1".into()) }
        );
        assert_eq!(
            parse_request(r#"{"op":"shutdown"}"#).unwrap(),
            Request::Shutdown
        );
        assert_eq!(parse_request(r#"{"op":"list"}"#).unwrap(), Request::List);
        assert_eq!(
            parse_request(r#"{"op":"drop","model":"m1"}"#).unwrap(),
            Request::Drop { model: "m1".into() }
        );
        let r = parse_request(r#"{"op":"ingest","points":[[1,2],[3,4]]}"#).unwrap();
        assert_eq!(
            r,
            Request::Ingest {
                model: None,
                points: vec![
                    WireRow::Dense(vec![1.0, 2.0]),
                    WireRow::Dense(vec![3.0, 4.0]),
                ],
                rounds: 1,
                seconds: f64::INFINITY,
            }
        );
        // sparse point encoding, dense rows mixable in one request
        let r = parse_request(
            r#"{"op":"predict","points":[{"indices":[1,3],"values":[0.5,2],"dim":5},[0,0,0,0,0]]}"#,
        )
        .unwrap();
        assert_eq!(
            r,
            Request::Predict {
                model: None,
                points: vec![
                    WireRow::Sparse {
                        dim: 5,
                        idx: vec![1, 3],
                        vals: vec![0.5, 2.0]
                    },
                    WireRow::Dense(vec![0.0; 5]),
                ],
                binary: false,
            }
        );
        let r = parse_request(r#"{"op":"step","rounds":4,"seconds":0.5}"#).unwrap();
        assert_eq!(
            r,
            Request::Step { model: None, rounds: 4, seconds: 0.5 }
        );
        let r = parse_request(
            r#"{"op":"snapshot","model":"m2","path":"m.json","include_data":false}"#,
        )
        .unwrap();
        assert_eq!(
            r,
            Request::Snapshot {
                model: Some("m2".into()),
                path: "m.json".into(),
                include_data: false
            }
        );
        let r = parse_request(
            r#"{"op":"create","model":"m3","k":5,"dim":16,"algo":"gb","b0":64,"rho":"inf","seed":9,"threads":2}"#,
        )
        .unwrap();
        match r {
            Request::Create { model, dim, cfg } => {
                assert_eq!(model.as_deref(), Some("m3"));
                assert_eq!(dim, 16);
                assert_eq!(cfg.k, 5);
                assert_eq!(cfg.algo, Algo::GbRho);
                assert_eq!(cfg.b0, 64);
                assert_eq!(cfg.seed, 9);
                // requested 2, clamped to host parallelism on tiny hosts
                assert!(cfg.threads >= 1 && cfg.threads <= 2);
            }
            other => panic!("parsed {other:?}"),
        }
        // a remote peer cannot request more OS threads than the host has
        let r = parse_request(
            r#"{"op":"create","k":2,"dim":3,"threads":100000000}"#,
        )
        .unwrap();
        match r {
            Request::Create { cfg, .. } => {
                let host = std::thread::available_parallelism()
                    .map(|x| x.get())
                    .unwrap_or(1);
                assert!(cfg.threads <= host, "threads {} > host {host}", cfg.threads);
            }
            other => panic!("parsed {other:?}"),
        }
        for bad in [
            "not json",
            r#"{"no_op":1}"#,
            r#"{"op":"transmogrify"}"#,
            r#"{"op":"predict"}"#,
            r#"{"op":"predict","points":[1]}"#,
            r#"{"op":"predict","points":[["x"]]}"#,
            r#"{"op":"predict","model":7,"points":[[1]]}"#,
            r#"{"op":"predict","points":[{"indices":[1],"values":[1,2],"dim":4}]}"#,
            r#"{"op":"predict","points":[{"indices":[3,1],"values":[1,2],"dim":4}]}"#,
            r#"{"op":"predict","points":[{"indices":[9],"values":[1],"dim":4}]}"#,
            r#"{"op":"ingest","points":[{"indices":[1],"values":[1]}]}"#,
            r#"{"op":"ingest","points":[{"indices":[0],"values":[1e400],"dim":2}]}"#,
            r#"{"op":"step","rounds":-1}"#,
            r#"{"op":"step","rounds":1.5}"#,
            r#"{"op":"snapshot"}"#,
            r#"{"op":"ingest","points":[[1e400]]}"#,
            r#"{"op":"create","dim":4}"#,
            r#"{"op":"create","k":3}"#,
            r#"{"op":"create","k":0,"dim":4}"#,
            r#"{"op":"create","k":3,"dim":4,"algo":"warp"}"#,
            r#"{"op":"drop"}"#,
        ] {
            assert!(parse_request(bad).is_err(), "should reject: {bad}");
        }
    }

    #[test]
    fn errors_do_not_close_the_stream() {
        let reg = ready_registry();
        let input = "{\"op\":\"bogus\"}\n\n{\"op\":\"stats\"}\n";
        let mut out = Vec::new();
        let shutdown =
            serve_lines(&reg, std::io::Cursor::new(input), &mut out).unwrap();
        assert!(!shutdown, "EOF, not shutdown");
        let lines: Vec<&str> =
            std::str::from_utf8(&out).unwrap().trim().lines().collect();
        assert_eq!(lines.len(), 2, "blank line skipped, two responses");
        let first = Json::parse(lines[0]).unwrap();
        assert_eq!(first.get("ok").unwrap().as_bool(), Some(false));
        let second = Json::parse(lines[1]).unwrap();
        assert_eq!(second.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(second.get("op").unwrap().as_str(), Some("stats"));
        assert_eq!(second.get("model").unwrap().as_str(), Some("default"));
    }

    #[test]
    fn shutdown_terminates_and_reports() {
        let reg = ready_registry();
        let input = "{\"op\":\"shutdown\"}\n{\"op\":\"stats\"}\n";
        let mut out = Vec::new();
        let shutdown =
            serve_lines(&reg, std::io::Cursor::new(input), &mut out).unwrap();
        assert!(shutdown);
        let lines: Vec<&str> =
            std::str::from_utf8(&out).unwrap().trim().lines().collect();
        assert_eq!(lines.len(), 1, "nothing served after shutdown");
    }

    #[test]
    fn ingest_then_stats_reflects_growth() {
        let reg = ready_registry();
        let input = "{\"op\":\"ingest\",\"points\":[[0.5,0.5,0.5,0.5]],\"rounds\":0}\n\
                     {\"op\":\"stats\"}\n";
        let mut out = Vec::new();
        serve_lines(&reg, std::io::Cursor::new(input), &mut out).unwrap();
        let lines: Vec<&str> =
            std::str::from_utf8(&out).unwrap().trim().lines().collect();
        let ingest = Json::parse(lines[0]).unwrap();
        assert_eq!(ingest.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(ingest.get("n").unwrap().as_usize(), Some(301));
        let stats = Json::parse(lines[1]).unwrap();
        assert_eq!(stats.get("n_total").unwrap().as_usize(), Some(301));
    }

    #[test]
    fn create_list_route_drop_over_the_protocol() {
        let reg = ready_registry();
        // create a second model with a different shape
        let (resp, _) = handle_line(
            &reg,
            r#"{"op":"create","model":"wide","k":2,"dim":6,"algo":"tb"}"#,
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
        // duplicate name is an error, stream survives
        let (resp, quit) = handle_line(
            &reg,
            r#"{"op":"create","model":"wide","k":2,"dim":6}"#,
        );
        assert!(!quit);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        // list shows both, name-ordered
        let (resp, _) = handle_line(&reg, r#"{"op":"list"}"#);
        let models = resp.get("models").unwrap().as_arr().unwrap();
        let names: Vec<&str> = models
            .iter()
            .map(|m| m.get("model").unwrap().as_str().unwrap())
            .collect();
        assert_eq!(names, vec!["default", "wide"]);
        // requests route by dimension: 6-dim ingest fits "wide" only
        let (resp, _) = handle_line(
            &reg,
            r#"{"op":"ingest","model":"wide","points":[[1,2,3,4,5,6]],"rounds":0}"#,
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
        let (resp, _) = handle_line(
            &reg,
            r#"{"op":"ingest","points":[[1,2,3,4,5,6]],"rounds":0}"#,
        );
        assert_eq!(
            resp.get("ok").unwrap().as_bool(),
            Some(false),
            "default model is 4-dim; 6-dim ingest must not route there"
        );
        // drop, then the name is gone
        let (resp, _) = handle_line(&reg, r#"{"op":"drop","model":"wide"}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
        let (resp, _) = handle_line(&reg, r#"{"op":"stats","model":"wide"}"#);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
    }

    #[test]
    fn snapshot_op_confined_to_snapshot_dir() {
        let reg = ready_registry();
        reg.resolve(None)
            .unwrap()
            .with_session_mut(|s| {
                s.set_snapshot_dir(std::env::temp_dir());
                Ok(())
            })
            .unwrap();
        // path-like names are rejected outright
        for bad in ["../escape.json", "/etc/owned", "a/b.json", "C:evil.json", "..", ""] {
            let req = format!(
                "{{\"op\":\"snapshot\",\"path\":{}}}",
                Json::Str(bad.to_string()).to_string()
            );
            let (resp, _) = handle_line(&reg, &req);
            assert_eq!(
                resp.get("ok").unwrap().as_bool(),
                Some(false),
                "accepted {bad:?}"
            );
        }
        // a bare file name lands inside the configured directory
        let (resp, _) = handle_line(
            &reg,
            r#"{"op":"snapshot","path":"nmbkm-proto-snap-test.json"}"#,
        );
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
        let written = std::env::temp_dir().join("nmbkm-proto-snap-test.json");
        assert!(written.exists());
        assert!(resp.get("bytes").unwrap().as_usize().unwrap() > 0);
        std::fs::remove_file(&written).ok();
    }

    #[test]
    fn predict_dimension_mismatch_is_an_ok_false() {
        let reg = ready_registry();
        let (resp, quit) =
            handle_line(&reg, r#"{"op":"predict","points":[[1,2]]}"#);
        assert!(!quit);
        assert_eq!(resp.get("ok").unwrap().as_bool(), Some(false));
        assert!(resp.get("error").unwrap().as_str().unwrap().contains("dimension"));
    }
}
