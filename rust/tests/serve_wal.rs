//! Integration tests for the durable op log (`serve::wal`): crash
//! recovery replays the log into a bit-identical registry, truncating
//! the log at *every byte offset* recovers the longest clean prefix, a
//! graceful drain leaves nothing to replay, threshold checkpoints cut
//! the log, and corrupt inputs fail with clean errors instead of
//! replaying garbage.
//!
//! "Crash" here means dropping the primary registry without calling
//! `drain` — with `--fsync always` every acknowledged record is already
//! on disk, which is exactly the state a SIGKILL leaves behind (the CI
//! smoke test kills a real process; these tests cover the byte-level
//! contract).

use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::data::gaussian::GaussianMixture;
use nmbkm::data::Data;
use nmbkm::serve::protocol::{self, Request};
use nmbkm::serve::wal::{self, FsyncPolicy};
use nmbkm::serve::{ModelRegistry, WireRow};
use nmbkm::util::json::Json;
use std::fs;
use std::path::PathBuf;

/// Checkpoint threshold high enough that no test checkpoints unless it
/// asks to: recovery must come from the log alone.
const NO_CKPT: u64 = u64::MAX;

fn cfg(k: usize, b0: usize) -> RunConfig {
    RunConfig {
        algo: Algo::TbRho,
        k,
        b0,
        rho: Rho::Infinite,
        threads: 2,
        seed: 11,
        max_rounds: 50,
        max_seconds: 60.0,
        eval_every_secs: 0.0,
        ..Default::default()
    }
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("nmbkm-serve-wal-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    d
}

fn rows(data: &Data, lo: usize, hi: usize) -> Vec<WireRow> {
    let mut row = vec![0f32; data.dim()];
    (lo..hi)
        .map(|i| {
            data.write_row_dense(i, &mut row);
            WireRow::Dense(row.clone())
        })
        .collect()
}

/// Run one request through the real protocol layer (so WAL appends and
/// post-request checkpoints fire exactly as they do in production).
fn exec(reg: &ModelRegistry, req: &Request) -> Json {
    let (resp, _) = protocol::handle_request(reg, req);
    assert_eq!(
        resp.get("ok").and_then(Json::as_bool),
        Some(true),
        "request failed: {}",
        resp.to_string()
    );
    resp
}

/// The model's full serialised state — the bit-identity yardstick.
fn model_bytes(reg: &ModelRegistry, name: &str) -> String {
    reg.resolve(Some(name))
        .unwrap()
        .with_session(|s| Ok(s.snapshot(true)?.to_json().to_string()))
        .unwrap()
}

fn model(name: &str) -> Option<String> {
    Some(name.to_string())
}

/// A mixed workload: two models, ingests, a data-free step, a drop.
fn drive_phase1(reg: &ModelRegistry, data: &Data) {
    exec(reg, &Request::Create { model: model("m1"), dim: data.dim(), cfg: cfg(4, 16) });
    exec(
        reg,
        &Request::Ingest {
            model: model("m1"),
            points: rows(data, 0, 40),
            rounds: 2,
            seconds: f64::INFINITY,
        },
    );
    exec(
        reg,
        &Request::Ingest {
            model: model("m1"),
            points: rows(data, 40, 90),
            rounds: 3,
            seconds: f64::INFINITY,
        },
    );
    exec(reg, &Request::Step { model: model("m1"), rounds: 1, seconds: f64::INFINITY });
    exec(reg, &Request::Create { model: model("scratch"), dim: data.dim(), cfg: cfg(2, 8) });
    exec(
        reg,
        &Request::Ingest {
            model: model("scratch"),
            points: rows(data, 0, 20),
            rounds: 1,
            seconds: f64::INFINITY,
        },
    );
    exec(reg, &Request::Drop { model: "scratch".to_string() });
}

fn drive_phase2(reg: &ModelRegistry, data: &Data) {
    exec(
        reg,
        &Request::Ingest {
            model: model("m1"),
            points: rows(data, 90, 130),
            rounds: 2,
            seconds: f64::INFINITY,
        },
    );
    exec(reg, &Request::Step { model: model("m1"), rounds: 2, seconds: f64::INFINITY });
}

#[test]
fn crash_recovery_is_bit_identical() {
    let data = GaussianMixture::default_spec(4, 6).generate(130, 7);

    // reference: identical ops with no wal anywhere in the loop
    let reference = ModelRegistry::new();
    drive_phase1(&reference, &data);
    let want = model_bytes(&reference, "m1");

    // primary: same ops, every record fsynced; then "crash" (no drain)
    let dir = tmpdir("crash");
    let primary = ModelRegistry::new();
    let rec = wal::recover(&dir, FsyncPolicy::Always, NO_CKPT, &primary).unwrap();
    assert_eq!((rec.resumed_models, rec.replayed, rec.skipped), (0, 0, 0));
    primary.attach_wal(rec.wal.clone());
    drive_phase1(&primary, &data);
    assert_eq!(
        model_bytes(&primary, "m1"),
        want,
        "wal appends must not perturb training"
    );
    let logged = rec.wal.next_seq() - 1;
    assert!(logged >= 6, "expected >= 6 logged mutations, got {logged}");
    drop(primary);

    let revived = ModelRegistry::new();
    let rec2 = wal::recover(&dir, FsyncPolicy::Always, NO_CKPT, &revived).unwrap();
    assert_eq!(rec2.resumed_models, 0, "no checkpoint was ever cut");
    assert_eq!(rec2.replayed + rec2.skipped, logged);
    assert_eq!(rec2.wal.next_seq(), logged + 1);
    assert_eq!(model_bytes(&revived, "m1"), want);
    assert!(
        revived.resolve(Some("scratch")).is_err(),
        "dropped model must stay dropped through replay"
    );
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn truncating_wal_at_every_byte_recovers_longest_clean_prefix() {
    // magic(8) + version(1) + epoch(8) + first_seq(8)
    const SEG_HEADER_LEN: usize = 25;
    let data = GaussianMixture::default_spec(2, 3).generate(24, 3);

    let dir = tmpdir("trunc-src");
    let reg = ModelRegistry::new();
    let rec = wal::recover(&dir, FsyncPolicy::Always, NO_CKPT, &reg).unwrap();
    reg.attach_wal(rec.wal.clone());
    exec(&reg, &Request::Create { model: model("t"), dim: data.dim(), cfg: cfg(2, 4) });
    exec(
        &reg,
        &Request::Ingest {
            model: model("t"),
            points: rows(&data, 0, 8),
            rounds: 1,
            seconds: f64::INFINITY,
        },
    );
    exec(
        &reg,
        &Request::Ingest {
            model: model("t"),
            points: rows(&data, 8, 16),
            rounds: 2,
            seconds: f64::INFINITY,
        },
    );
    exec(&reg, &Request::Step { model: model("t"), rounds: 1, seconds: f64::INFINITY });
    exec(
        &reg,
        &Request::Ingest {
            model: model("t"),
            points: rows(&data, 16, 24),
            rounds: 1,
            seconds: f64::INFINITY,
        },
    );
    let live = model_bytes(&reg, "t");

    let segs: Vec<PathBuf> = fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "log"))
        .collect();
    assert_eq!(segs.len(), 1, "workload should fit one segment");
    let full = fs::read(&segs[0]).unwrap();
    let seg_name = segs[0].file_name().unwrap().to_owned();
    let scan = wal::scan_records(&full[SEG_HEADER_LEN..]);
    assert!(scan.torn.is_none());
    let n_records = scan.records.len();
    assert!(n_records >= 4, "expected >= 4 records, got {n_records}");

    // expected state after replaying exactly r records, for every r;
    // the full prefix must also equal the live run bit-for-bit
    let mut want: Vec<Option<String>> = Vec::new();
    for r in 0..=n_records {
        let fresh = ModelRegistry::new();
        for (record, _) in &scan.records[..r] {
            wal::apply_record(&fresh, record).unwrap();
        }
        want.push(
            fresh
                .resolve(Some("t"))
                .ok()
                .map(|_| model_bytes(&fresh, "t")),
        );
    }
    assert_eq!(want[n_records].as_deref(), Some(live.as_str()));

    let work = tmpdir("trunc-work");
    for cut in 0..=full.len() {
        let _ = fs::remove_dir_all(&work);
        fs::create_dir_all(&work).unwrap();
        fs::write(work.join(&seg_name), &full[..cut]).unwrap();
        let revived = ModelRegistry::new();
        let out = wal::recover(&work, FsyncPolicy::Never, NO_CKPT, &revived)
            .unwrap_or_else(|e| panic!("recover failed at cut {cut}: {e:#}"));
        // the longest clean prefix: records fully inside the cut
        let r = if cut < SEG_HEADER_LEN {
            0
        } else {
            scan.records
                .iter()
                .take_while(|(_, range)| SEG_HEADER_LEN + range.end <= cut)
                .count()
        };
        assert_eq!(out.replayed as usize, r, "cut {cut}");
        assert_eq!(out.wal.next_seq(), r as u64 + 1, "cut {cut}");
        assert_eq!(
            revived
                .resolve(Some("t"))
                .ok()
                .map(|_| model_bytes(&revived, "t")),
            want[r],
            "cut {cut}: recovered state must match a clean {r}-record replay"
        );
    }
    let _ = fs::remove_dir_all(&dir);
    let _ = fs::remove_dir_all(&work);
}

#[test]
fn graceful_drain_leaves_nothing_to_replay() {
    let data = GaussianMixture::default_spec(4, 6).generate(130, 7);
    let reference = ModelRegistry::new();
    drive_phase1(&reference, &data);
    drive_phase2(&reference, &data);
    let want = model_bytes(&reference, "m1");

    let dir = tmpdir("drain");
    let a = ModelRegistry::new();
    let rec =
        wal::recover(&dir, FsyncPolicy::parse("interval:5").unwrap(), NO_CKPT, &a)
            .unwrap();
    a.attach_wal(rec.wal.clone());
    drive_phase1(&a, &data);
    rec.wal.drain(&a).unwrap(); // graceful shutdown: sync + final checkpoint
    assert!(dir.join("manifest.json").exists());

    // restart resumes from the checkpoint — zero records to replay
    let b = ModelRegistry::new();
    let rec2 = wal::recover(&dir, FsyncPolicy::Always, NO_CKPT, &b).unwrap();
    assert_eq!(rec2.replayed, 0, "clean shutdown must leave an empty log");
    assert_eq!(rec2.resumed_models, 1);
    b.attach_wal(rec2.wal.clone());
    drive_phase2(&b, &data);
    assert_eq!(
        model_bytes(&b, "m1"),
        want,
        "checkpoint resume + fresh ops must retrace the uninterrupted run"
    );
    drop(b);

    // crash after phase 2: recovery = checkpoint + phase-2 replay
    let c = ModelRegistry::new();
    let rec3 = wal::recover(&dir, FsyncPolicy::Always, NO_CKPT, &c).unwrap();
    assert_eq!(rec3.resumed_models, 1);
    assert!(rec3.replayed >= 1);
    assert_eq!(model_bytes(&c, "m1"), want);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn checkpoints_truncate_the_log() {
    let data = GaussianMixture::default_spec(4, 6).generate(130, 7);
    let reference = ModelRegistry::new();
    drive_phase1(&reference, &data);
    let want = model_bytes(&reference, "m1");

    let dir = tmpdir("ckpt");
    let a = ModelRegistry::new();
    // 1-byte threshold: every mutation trips the post-request checkpoint
    let rec = wal::recover(&dir, FsyncPolicy::Always, 1, &a).unwrap();
    a.attach_wal(rec.wal.clone());
    drive_phase1(&a, &data);
    assert_eq!(model_bytes(&a, "m1"), want);

    // every acknowledged record is behind the checkpoint: the log is cut
    assert_eq!(rec.wal.oldest_retained().unwrap(), rec.wal.next_seq());
    assert!(dir.join("manifest.json").exists());
    assert!(dir.join("ckpt-m1.json").exists());
    assert!(
        !dir.join("ckpt-scratch.json").exists(),
        "dropped model's checkpoint snapshot must be collected"
    );

    let b = ModelRegistry::new();
    let rec2 = wal::recover(&dir, FsyncPolicy::Always, 1, &b).unwrap();
    assert_eq!((rec2.resumed_models, rec2.replayed), (1, 0));
    assert_eq!(model_bytes(&b, "m1"), want);
    let _ = fs::remove_dir_all(&dir);
}

#[test]
fn corrupt_wal_inputs_fail_cleanly() {
    let data = GaussianMixture::default_spec(2, 3).generate(24, 3);

    // two segments, so segment 1 is *interior* — corruption there must
    // refuse recovery rather than silently skip acknowledged records
    let dir = tmpdir("corrupt");
    let reg = ModelRegistry::new();
    let rec = wal::recover(&dir, FsyncPolicy::Always, NO_CKPT, &reg).unwrap();
    reg.attach_wal(rec.wal.clone());
    exec(&reg, &Request::Create { model: model("t"), dim: data.dim(), cfg: cfg(2, 4) });
    exec(
        &reg,
        &Request::Ingest {
            model: model("t"),
            points: rows(&data, 0, 12),
            rounds: 1,
            seconds: f64::INFINITY,
        },
    );
    rec.wal.rotate().unwrap();
    exec(
        &reg,
        &Request::Ingest {
            model: model("t"),
            points: rows(&data, 12, 24),
            rounds: 1,
            seconds: f64::INFINITY,
        },
    );
    let live = model_bytes(&reg, "t");

    let mut segs: Vec<PathBuf> = fs::read_dir(&dir)
        .unwrap()
        .map(|e| e.unwrap().path())
        .filter(|p| p.extension().is_some_and(|x| x == "log"))
        .collect();
    segs.sort();
    assert_eq!(segs.len(), 2);
    let good = fs::read(&segs[0]).unwrap();

    // corrupt interior segment header (magic byte)
    let mut bad = good.clone();
    bad[0] ^= 0xff;
    fs::write(&segs[0], &bad).unwrap();
    let err = match wal::recover(&dir, FsyncPolicy::Always, NO_CKPT, &ModelRegistry::new()) {
        Ok(_) => panic!("corrupt interior segment header must fail recovery"),
        Err(e) => e,
    };
    assert!(
        format!("{err:#}").contains("segment"),
        "unexpected error: {err:#}"
    );

    // corrupt interior record payload (crc mismatch)
    let mut bad = good.clone();
    let at = bad.len() - 4;
    bad[at] ^= 0xff;
    fs::write(&segs[0], &bad).unwrap();
    let err = match wal::recover(&dir, FsyncPolicy::Always, NO_CKPT, &ModelRegistry::new()) {
        Ok(_) => panic!("corrupt interior record must fail recovery"),
        Err(e) => e,
    };
    assert!(
        format!("{err:#}").contains("refusing to skip acknowledged records"),
        "unexpected error: {err:#}"
    );

    // restore → recovery works again and is still bit-identical
    fs::write(&segs[0], &good).unwrap();
    let reg2 = ModelRegistry::new();
    let rec2 = wal::recover(&dir, FsyncPolicy::Always, NO_CKPT, &reg2).unwrap();
    assert_eq!(model_bytes(&reg2, "t"), live);

    // manifest corruption: parse error, bad version, dangling file ref
    rec2.wal.drain(&reg2).unwrap();
    let manifest = dir.join("manifest.json");
    let good_manifest = fs::read_to_string(&manifest).unwrap();
    for bad in [
        "{",
        "{\"version\":2,\"epoch\":\"1\",\"models\":[]}",
        "{\"version\":1,\"epoch\":\"1\",\"models\":[{\"name\":\"x\",\"file\":\"nope.json\",\"seq\":\"1\"}]}",
    ] {
        fs::write(&manifest, bad).unwrap();
        assert!(
            wal::recover(&dir, FsyncPolicy::Always, NO_CKPT, &ModelRegistry::new())
                .is_err(),
            "manifest {bad:?} must fail recovery"
        );
    }

    // a corrupt checkpoint snapshot errors cleanly too (never panics)
    fs::write(&manifest, &good_manifest).unwrap();
    fs::write(dir.join("ckpt-t.json"), "not a snapshot").unwrap();
    assert!(
        wal::recover(&dir, FsyncPolicy::Always, NO_CKPT, &ModelRegistry::new())
            .is_err(),
        "garbage checkpoint snapshot must fail recovery"
    );
    let _ = fs::remove_dir_all(&dir);
}
