//! Row-major dense matrices and the dense distance kernels.
//!
//! The assignment hot loop uses the norms decomposition
//! `‖x−c‖² = ‖x‖² + ‖c‖² − 2⟨x,c⟩` so the inner loop is a pure dot
//! product — the same form the L1 Pallas kernel uses on the MXU. The
//! arithmetic now lives in [`crate::linalg::simd`], which dispatches to
//! explicit AVX2/SSE2/NEON kernels at runtime while staying bit-identical
//! to the 8-way unrolled scalar reference; this module re-exports the
//! dispatched entry points under their historical names.

pub use crate::linalg::simd::{add_into, dot, nearest, sq_norm, sub_from};

/// Row-major `rows × cols` matrix of `f32`.
#[derive(Clone, Debug, PartialEq)]
pub struct DenseMatrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl DenseMatrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Self { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape/data mismatch");
        Self { rows, cols, data }
    }

    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    /// ‖row_i‖² for every row.
    pub fn row_sq_norms(&self) -> Vec<f32> {
        (0..self.rows).map(|i| sq_norm(self.row(i))).collect()
    }

    /// Materialise a row permutation: `out.row(i) = self.row(perm[i])`.
    pub fn permute_rows(&self, perm: &[usize]) -> DenseMatrix {
        assert_eq!(perm.len(), self.rows);
        let mut out = DenseMatrix::zeros(self.rows, self.cols);
        for (i, &p) in perm.iter().enumerate() {
            out.row_mut(i).copy_from_slice(self.row(p));
        }
        out
    }

    /// Rows `[lo, hi)` as a new matrix.
    pub fn slice_rows(&self, lo: usize, hi: usize) -> DenseMatrix {
        assert!(lo <= hi && hi <= self.rows);
        DenseMatrix::from_vec(
            hi - lo,
            self.cols,
            self.data[lo * self.cols..hi * self.cols].to_vec(),
        )
    }
}

/// Exact squared distance (no norms trick; used by oracles and tests).
#[inline]
pub fn sq_dist(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0f32;
    for i in 0..a.len() {
        let d = a[i] - b[i];
        s += d * d;
    }
    s
}

/// Squared distance via the norms decomposition (hot-path form; can be
/// slightly negative from cancellation, clamped to 0).
#[inline]
pub fn sq_dist_norms(x: &[f32], xn: f32, c: &[f32], cn: f32) -> f32 {
    (xn + cn - 2.0 * dot(x, c)).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::propcheck::{gen, Cases};

    #[test]
    fn dot_matches_naive() {
        Cases::new(100).run(|rng| {
            let n = rng.below(200);
            let a = gen::matrix(rng, 1, n);
            let b = gen::matrix(rng, 1, n);
            let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
            let got = dot(&a, &b);
            assert!(
                (got - naive).abs() <= 1e-3 * (1.0 + naive.abs()),
                "n={n} got={got} naive={naive}"
            );
        });
    }

    #[test]
    fn sq_dist_norms_matches_exact() {
        Cases::new(100).run(|rng| {
            let d = rng.below(100) + 1;
            let a = gen::matrix(rng, 1, d);
            let b = gen::matrix(rng, 1, d);
            let exact = sq_dist(&a, &b);
            let via = sq_dist_norms(&a, sq_norm(&a), &b, sq_norm(&b));
            assert!(
                (exact - via).abs() <= 1e-2 * (1.0 + exact.abs()),
                "d={d} exact={exact} via={via}"
            );
        });
    }

    #[test]
    fn nearest_matches_bruteforce() {
        Cases::new(60).run(|rng| {
            let (_, d, k) = gen::shape(rng, 1, 50, 12);
            let c = DenseMatrix::from_vec(k, d, gen::matrix(rng, k, d));
            let cn = c.row_sq_norms();
            let x = gen::matrix(rng, 1, d);
            let xn = sq_norm(&x);
            let (j, d2) = nearest(&x, xn, &c, &cn);
            let brute: Vec<f32> =
                (0..k).map(|j| sq_dist(&x, c.row(j))).collect();
            let jb = brute
                .iter()
                .enumerate()
                .min_by(|a, b| a.1.total_cmp(b.1))
                .unwrap()
                .0;
            // allow tie-or-epsilon disagreement on the index, but the
            // achieved distance must be ≈ optimal
            assert!(
                (d2 - brute[jb]).abs() <= 1e-2 * (1.0 + brute[jb].abs()),
                "d2={d2} best={} j={j} jb={jb}",
                brute[jb]
            );
        });
    }

    #[test]
    fn permute_and_slice() {
        let m = DenseMatrix::from_vec(3, 2, vec![0., 1., 10., 11., 20., 21.]);
        let p = m.permute_rows(&[2, 0, 1]);
        assert_eq!(p.row(0), &[20., 21.]);
        assert_eq!(p.row(1), &[0., 1.]);
        let s = p.slice_rows(1, 3);
        assert_eq!(s.rows, 2);
        assert_eq!(s.row(1), &[10., 11.]);
    }

    #[test]
    fn add_sub_roundtrip() {
        let mut acc = vec![1.0f64; 5];
        let x: Vec<f32> = vec![0.5; 5];
        add_into(&mut acc, &x);
        sub_from(&mut acc, &x);
        for v in acc {
            assert!((v - 1.0).abs() < 1e-12);
        }
    }

    #[test]
    #[should_panic]
    fn from_vec_checks_shape() {
        DenseMatrix::from_vec(2, 2, vec![0.0; 3]);
    }
}
