//! Integration tests for the serving layer: snapshot round trips are
//! bit-exact, resumed sessions retrace uninterrupted ones, online ingest
//! preserves the each-point-counts-exactly-once invariant, and the JSONL
//! protocol answers predict queries identically to the in-process
//! engine — over in-memory pipes and over real TCP.

use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::coordinator::Pool;
use nmbkm::data::gaussian::GaussianMixture;
use nmbkm::data::Data;
use nmbkm::kmeans::assign::{AssignEngine, NativeEngine, Sel};
use nmbkm::kmeans::state::{SuffStats, UNASSIGNED};
use nmbkm::linalg::dense::DenseMatrix;
use nmbkm::serve::{protocol, session, ModelRegistry, Snapshot};
use std::sync::Arc;
use nmbkm::util::json::Json;
use nmbkm::util::propcheck::Cases;

fn cfg(algo: Algo, k: usize, b0: usize, rounds: usize) -> RunConfig {
    RunConfig {
        algo,
        k,
        b0,
        rho: Rho::Infinite,
        threads: 2,
        seed: 11,
        max_rounds: rounds,
        max_seconds: 60.0,
        eval_every_secs: 0.0,
        ..Default::default()
    }
}

fn rows_of(data: &Data, lo: usize, hi: usize) -> Vec<Vec<f32>> {
    let mut out = Vec::with_capacity(hi - lo);
    let mut row = vec![0f32; data.dim()];
    for i in lo..hi {
        data.write_row_dense(i, &mut row);
        out.push(row.clone());
    }
    out
}

fn f32_bits(xs: &[f32]) -> Vec<u32> {
    xs.iter().map(|x| x.to_bits()).collect()
}

fn f64_bits(xs: &[f64]) -> Vec<u64> {
    xs.iter().map(|x| x.to_bits()).collect()
}

#[test]
fn snapshot_roundtrip_bit_exact_both_algorithms() {
    for algo in [Algo::GbRho, Algo::TbRho] {
        let data = GaussianMixture::default_spec(4, 6).generate(700, 1);
        let (trained, _) = session::train(&data, &cfg(algo, 4, 64, 5)).unwrap();
        let snap = trained.snapshot(true).unwrap();
        let text = snap.to_json().to_string();
        let back = Snapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back.cfg, snap.cfg, "{algo:?}");
        let (a, b) = (&back.state, &snap.state);
        assert_eq!(f32_bits(&a.cent.c.data), f32_bits(&b.cent.c.data));
        assert_eq!(f32_bits(&a.cent.norms), f32_bits(&b.cent.norms));
        assert_eq!(f32_bits(&a.cent.p), f32_bits(&b.cent.p));
        assert_eq!(f64_bits(&a.stats.s), f64_bits(&b.stats.s));
        assert_eq!(f64_bits(&a.stats.v), f64_bits(&b.stats.v));
        assert_eq!(f64_bits(&a.stats.sse), f64_bits(&b.stats.sse));
        assert_eq!(a.assign.label, b.assign.label);
        assert_eq!(f32_bits(&a.assign.dist2), f32_bits(&b.assign.dist2));
        assert_eq!((a.b_prev, a.b, a.n), (b.b_prev, b.b, b.n));
        assert_eq!(back.rng.to_parts(), snap.rng.to_parts());
        assert_eq!(back.rounds, snap.rounds);
        // re-serialisation is byte-identical: stable artifact format
        assert_eq!(back.to_json().to_string(), text);
    }
}

#[test]
fn snapshot_file_roundtrip_property() {
    // random shapes, algorithms and training lengths; every save→load
    // must reproduce the model bit-for-bit
    Cases::new(8).run(|rng| {
        let k = 2 + rng.below(4);
        let d = 2 + rng.below(6);
        let n = (k * 10).max(60) + rng.below(200);
        let algo = if rng.below(2) == 0 { Algo::GbRho } else { Algo::TbRho };
        let rounds = 1 + rng.below(5);
        let data = GaussianMixture::default_spec(k, d).generate(n, rng.next_u64());
        let mut c = cfg(algo, k, 16 + rng.below(64), rounds);
        c.seed = rng.next_u64();
        let (trained, _) = session::train(&data, &c).unwrap();
        let snap = trained.snapshot(true).unwrap();
        let path = std::env::temp_dir()
            .join(format!("nmbkm-prop-snap-{:x}.json", rng.next_u64()));
        snap.save(&path).unwrap();
        let back = Snapshot::load(&path).unwrap();
        std::fs::remove_file(&path).ok();
        assert_eq!(
            back.to_json().to_string(),
            snap.to_json().to_string(),
            "artifact not byte-stable for k={k} d={d} n={n} {algo:?}"
        );
        // usage mask semantics: exactly the seen prefix is marked used
        let st = &back.state;
        for i in 0..st.n {
            assert_eq!(st.assign.label[i] != UNASSIGNED, i < st.b_prev);
        }
    });
}

#[test]
fn resumed_session_retraces_uninterrupted_run() {
    for algo in [Algo::GbRho, Algo::TbRho] {
        let data = GaussianMixture::default_spec(5, 8).generate(1200, 9);
        // uninterrupted: 4 + 3 rounds in one session
        let (mut straight, _) = session::train(&data, &cfg(algo, 5, 100, 4)).unwrap();
        straight.step(3, 1e9).unwrap();
        // interrupted: 4 rounds, snapshot to JSON and back, 3 more
        let (paused, _) = session::train(&data, &cfg(algo, 5, 100, 4)).unwrap();
        let text = paused.snapshot(true).unwrap().to_json().to_string();
        let snap = Snapshot::from_json(&Json::parse(&text).unwrap()).unwrap();
        let mut resumed = session::OnlineSession::resume(snap).unwrap();
        resumed.step(3, 1e9).unwrap();

        let a = straight.centroids().unwrap();
        let b = resumed.centroids().unwrap();
        assert_eq!(
            f32_bits(&a.c.data),
            f32_bits(&b.c.data),
            "{algo:?}: resume diverged from the uninterrupted run"
        );
        assert_eq!(straight.rounds(), resumed.rounds());
        let qs = rows_of(&data, 0, 30);
        let (la, da) = straight.predict_rows(&qs).unwrap();
        let (lb, db) = resumed.predict_rows(&qs).unwrap();
        assert_eq!(la, lb);
        assert_eq!(f32_bits(&da), f32_bits(&db));
    }
}

#[test]
fn online_ingest_counts_every_point_exactly_once() {
    let full = GaussianMixture::default_spec(4, 6).generate(900, 3);
    let head = full.slice(0, 500);
    let (mut s, _) = session::train(&head, &cfg(Algo::TbRho, 4, 64, 6)).unwrap();
    // stream the remaining 400 points in chunks, training in between
    for chunk in 0..4 {
        let lo = 500 + chunk * 100;
        s.ingest_rows(&rows_of(&full, lo, lo + 100)).unwrap();
        s.step(8, 1e9).unwrap();
        let st = s.snapshot(true).unwrap().state;
        // Σ v(j) = number of points in the seen prefix — nothing counted
        // twice, nothing dropped (paper §3.1)
        let total: f64 = st.stats.v.iter().sum();
        assert_eq!(total as usize, st.b_prev, "chunk {chunk}");
        // and the statistics agree with a from-scratch rebuild
        let fresh = SuffStats::rebuild(
            s.data(),
            4,
            0..st.b_prev,
            &st.assign.label,
            &st.assign.dist2,
        );
        let drift = st.stats.max_abs_diff(&fresh);
        assert!(drift < 1e-5, "chunk {chunk}: stats drifted by {drift}");
    }
    assert_eq!(s.data().n(), 900);
    // the controller must eventually grow over the streamed points
    for _ in 0..50 {
        let st = s.snapshot(true).unwrap().state;
        if st.b_prev > 500 {
            break;
        }
        s.step(5, 1e9).unwrap();
    }
    let st = s.snapshot(true).unwrap().state;
    assert!(st.b_prev > 500, "streamed points never entered the batch");
}

#[test]
fn protocol_predict_parity_with_engine() {
    let data = GaussianMixture::default_spec(4, 7).generate(600, 5);
    let (s, _) = session::train(&data, &cfg(Algo::TbRho, 4, 64, 5)).unwrap();
    let queries = rows_of(&data, 50, 90);

    // reference: straight through the in-process engine
    let cent = s.centroids().unwrap().clone();
    let n = queries.len();
    let mut flat = Vec::new();
    for q in &queries {
        flat.extend_from_slice(q);
    }
    let qdata = Data::dense(DenseMatrix::from_vec(n, 7, flat));
    let mut ref_lbl = vec![0u32; n];
    let mut ref_d2 = vec![0f32; n];
    NativeEngine::default().assign(
        &qdata,
        Sel::Range(0, n),
        &cent,
        &Pool::new(2),
        &mut ref_lbl,
        &mut ref_d2,
    );

    // same queries over the JSONL protocol (implicit default model)
    let reg = ModelRegistry::with_default(s);
    let mut points = String::from("[");
    for (t, q) in queries.iter().enumerate() {
        if t > 0 {
            points.push(',');
        }
        let coords: Vec<String> = q.iter().map(|x| format!("{x}")).collect();
        points.push_str(&format!("[{}]", coords.join(",")));
    }
    points.push(']');
    let input = format!("{{\"op\":\"predict\",\"points\":{points}}}\n");
    let mut out = Vec::new();
    protocol::serve_lines(&reg, std::io::Cursor::new(input), &mut out).unwrap();
    let resp = Json::parse(std::str::from_utf8(&out).unwrap().trim()).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    let labels: Vec<u32> = resp
        .get("labels")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as u32)
        .collect();
    let d2: Vec<f32> = resp
        .get("d2")
        .unwrap()
        .as_arr()
        .unwrap()
        .iter()
        .map(|x| x.as_f64().unwrap() as f32)
        .collect();
    assert_eq!(labels, ref_lbl, "protocol labels != engine labels");
    // the JSON round trip must not perturb a single bit of the scores
    assert_eq!(f32_bits(&d2), f32_bits(&ref_d2));
}

#[test]
fn tcp_server_end_to_end() {
    use std::io::{BufRead, BufReader, Write};

    let Ok(listener) = std::net::TcpListener::bind("127.0.0.1:0") else {
        eprintln!("skipping tcp test: cannot bind loopback");
        return;
    };
    let addr = listener.local_addr().unwrap();
    let server = std::thread::spawn(move || {
        let data = GaussianMixture::default_spec(3, 5).generate(400, 2);
        let (s, _) =
            session::train(&data, &cfg(Algo::GbRho, 3, 64, 4)).unwrap();
        let reg = Arc::new(ModelRegistry::with_default(s));
        nmbkm::serve::server::serve_listener(reg, listener).unwrap();
    });

    let mut conn = std::net::TcpStream::connect(addr).unwrap();
    let mut reader = BufReader::new(conn.try_clone().unwrap());
    let mut line = String::new();

    conn.write_all(b"{\"op\":\"stats\"}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    let stats = Json::parse(line.trim()).unwrap();
    assert_eq!(stats.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(stats.get("n_total").unwrap().as_usize(), Some(400));

    line.clear();
    conn.write_all(b"{\"op\":\"predict\",\"points\":[[0,0,0,0,0]]}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    let resp = Json::parse(line.trim()).unwrap();
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true));
    assert_eq!(resp.get("labels").unwrap().as_arr().unwrap().len(), 1);

    line.clear();
    conn.write_all(b"{\"op\":\"shutdown\"}\n").unwrap();
    reader.read_line(&mut line).unwrap();
    assert_eq!(
        Json::parse(line.trim()).unwrap().get("op").unwrap().as_str(),
        Some("shutdown")
    );
    server.join().expect("server thread exits cleanly after shutdown");
}

#[test]
fn end_to_end_train_snapshot_serve_flow() {
    // the acceptance-criteria flow, in-process: train --save, resume,
    // ingest a fresh chunk, answer predict queries
    let corpus = GaussianMixture::default_spec(6, 10).generate(2000, 21);
    let history = corpus.slice(0, 1500);
    let (trained, report) =
        session::train(&history, &cfg(Algo::TbRho, 6, 128, 10)).unwrap();
    assert!(report.rounds_run >= 1);
    let path = std::env::temp_dir().join("nmbkm-e2e-flow.json");
    trained.snapshot(true).unwrap().save(&path).unwrap();

    let served =
        session::OnlineSession::resume(Snapshot::load(&path).unwrap()).unwrap();
    std::fs::remove_file(&path).ok();
    let reg = ModelRegistry::with_default(served);
    let (resp, _) = protocol::handle_line(&reg, r#"{"op":"stats"}"#);
    assert_eq!(resp.get("n_total").unwrap().as_usize(), Some(1500));

    // fresh chunk arrives over the protocol
    let fresh = rows_of(&corpus, 1500, 1510);
    let coords: Vec<String> = fresh
        .iter()
        .map(|q| {
            let xs: Vec<String> = q.iter().map(|x| format!("{x}")).collect();
            format!("[{}]", xs.join(","))
        })
        .collect();
    let req = format!(
        "{{\"op\":\"ingest\",\"points\":[{}],\"rounds\":2}}",
        coords.join(",")
    );
    let (resp, _) = protocol::handle_line(&reg, &req);
    assert_eq!(resp.get("ok").unwrap().as_bool(), Some(true), "{resp:?}");
    assert_eq!(resp.get("n").unwrap().as_usize(), Some(1510));

    let entry = reg.resolve(None).unwrap();
    let (lbl, d2) = entry.predict(&rows_of(&corpus, 0, 25)).unwrap();
    assert_eq!(lbl.len(), 25);
    assert!(lbl.iter().all(|&j| (j as usize) < 6));
    assert!(d2.iter().all(|&x| x.is_finite()));
}
