//! The serve layer's metric surface: named handles into the global
//! [`obs`] registry (server-level request/connection/byte counters, one
//! request-latency histogram, per-model op counters + latency
//! histograms), plus the merge that turns the registry **and** the
//! polled sources — SIMD dispatch tallies, per-model transpose-cache
//! counters — into one sample set. Both exposures read that merge: the
//! protocol's `{"op":"metrics"}` JSON and the `--metrics-addr`
//! Prometheus endpoint, so the two can never drift apart.

use crate::linalg::simd;
use crate::obs::{self, export, Counter, Gauge, Histogram, Sample, Value};
use crate::serve::protocol::Request;
use crate::serve::registry::ModelRegistry;
use crate::util::json::Json;
use std::sync::{Arc, OnceLock};

/// Server-level handles, interned once per process.
pub struct ServeMetrics {
    /// End-to-end latency of every protocol request (both transports).
    pub request_seconds: Arc<Histogram>,
    /// `ok:false` responses (any cause, either transport).
    pub errors: Arc<Counter>,
    pub conns_opened: Arc<Counter>,
    pub conns_closed: Arc<Counter>,
    /// Binary frames served (requests, not responses).
    pub frames: Arc<Counter>,
    pub frame_bytes_read: Arc<Counter>,
    pub frame_bytes_written: Arc<Counter>,
    pub jsonl_bytes_read: Arc<Counter>,
    pub jsonl_bytes_written: Arc<Counter>,
    /// Connections closed because a read/write exceeded
    /// `--conn-timeout` (slowloris / stalled-peer defence).
    pub conn_timeouts: Arc<Counter>,
    /// Connections currently admitted (event-loop gauge).
    pub open_connections: Arc<Gauge>,
    /// Backpressure episodes: a peer's write queue filled past its cap
    /// and the server stopped reading from it until the queue drained.
    pub conn_backpressure: Arc<Counter>,
    /// Admission-control refusals by limit
    /// (`nmbkm_overloaded_total{reason=…}`); each one answered with a
    /// structured `overloaded` error, never a hang.
    pub overloaded_conns: Arc<Counter>,
    pub overloaded_inflight: Arc<Counter>,
    pub overloaded_bytes: Arc<Counter>,
    /// Models evicted under `--max-resident`/idle pressure
    /// (checkpoint-then-drop; they lazily reload on next use).
    pub model_evictions: Arc<Counter>,
    /// Evicted models transparently reloaded by a request.
    pub model_reloads: Arc<Counter>,
    op_create: Arc<Counter>,
    op_list: Arc<Counter>,
    op_drop: Arc<Counter>,
    op_ingest: Arc<Counter>,
    op_predict: Arc<Counter>,
    op_step: Arc<Counter>,
    op_stats: Arc<Counter>,
    op_snapshot: Arc<Counter>,
    op_metrics: Arc<Counter>,
    op_sync_info: Arc<Counter>,
    op_wal_fetch: Arc<Counter>,
    op_sync_snapshot: Arc<Counter>,
    op_promote: Arc<Counter>,
    op_shutdown: Arc<Counter>,
    op_invalid: Arc<Counter>,
}

impl ServeMetrics {
    fn new() -> ServeMetrics {
        let reg = obs::registry();
        let opc = |op: &str| reg.counter("nmbkm_requests_total", &[("op", op)]);
        ServeMetrics {
            request_seconds: reg.histogram("nmbkm_request_seconds", &[]),
            errors: reg.counter("nmbkm_request_errors_total", &[]),
            conns_opened: reg.counter("nmbkm_connections_opened_total", &[]),
            conns_closed: reg.counter("nmbkm_connections_closed_total", &[]),
            frames: reg.counter("nmbkm_frames_total", &[]),
            frame_bytes_read: reg
                .counter("nmbkm_bytes_read_total", &[("transport", "frame")]),
            frame_bytes_written: reg
                .counter("nmbkm_bytes_written_total", &[("transport", "frame")]),
            jsonl_bytes_read: reg
                .counter("nmbkm_bytes_read_total", &[("transport", "jsonl")]),
            jsonl_bytes_written: reg
                .counter("nmbkm_bytes_written_total", &[("transport", "jsonl")]),
            conn_timeouts: reg.counter("nmbkm_connection_timeouts_total", &[]),
            open_connections: reg.gauge("nmbkm_open_connections", &[]),
            conn_backpressure: reg.counter("nmbkm_conn_backpressure_total", &[]),
            overloaded_conns: reg
                .counter("nmbkm_overloaded_total", &[("reason", "conns")]),
            overloaded_inflight: reg
                .counter("nmbkm_overloaded_total", &[("reason", "inflight")]),
            overloaded_bytes: reg
                .counter("nmbkm_overloaded_total", &[("reason", "request-bytes")]),
            model_evictions: reg.counter("nmbkm_model_evictions_total", &[]),
            model_reloads: reg.counter("nmbkm_model_reloads_total", &[]),
            op_create: opc("create"),
            op_list: opc("list"),
            op_drop: opc("drop"),
            op_ingest: opc("ingest"),
            op_predict: opc("predict"),
            op_step: opc("step"),
            op_stats: opc("stats"),
            op_snapshot: opc("snapshot"),
            op_metrics: opc("metrics"),
            op_sync_info: opc("sync-info"),
            op_wal_fetch: opc("wal-fetch"),
            op_sync_snapshot: opc("sync-snapshot"),
            op_promote: opc("promote"),
            op_shutdown: opc("shutdown"),
            op_invalid: opc("invalid"),
        }
    }

    /// The `nmbkm_requests_total{op=…}` counter for a request; anything
    /// unparseable lands on `op="invalid"`.
    pub fn op_counter(&self, op: &str) -> &Counter {
        match op {
            "create" => &self.op_create,
            "list" => &self.op_list,
            "drop" => &self.op_drop,
            "ingest" => &self.op_ingest,
            "predict" => &self.op_predict,
            "step" => &self.op_step,
            "stats" => &self.op_stats,
            "snapshot" => &self.op_snapshot,
            "metrics" => &self.op_metrics,
            "sync-info" => &self.op_sync_info,
            "wal-fetch" => &self.op_wal_fetch,
            "sync-snapshot" => &self.op_sync_snapshot,
            "promote" => &self.op_promote,
            "shutdown" => &self.op_shutdown,
            _ => &self.op_invalid,
        }
    }
}

/// The process-wide serve metric handles.
pub fn serve_metrics() -> &'static ServeMetrics {
    static M: OnceLock<ServeMetrics> = OnceLock::new();
    M.get_or_init(ServeMetrics::new)
}

/// The wire op name a parsed request counts under.
pub fn op_name(req: &Request) -> &'static str {
    match req {
        Request::Create { .. } => "create",
        Request::List => "list",
        Request::Drop { .. } => "drop",
        Request::Ingest { .. } => "ingest",
        Request::Predict { .. } => "predict",
        Request::Step { .. } => "step",
        Request::Stats { .. } => "stats",
        Request::Snapshot { .. } => "snapshot",
        Request::Metrics => "metrics",
        Request::SyncInfo => "sync-info",
        Request::WalFetch { .. } => "wal-fetch",
        Request::SyncSnapshot { .. } => "sync-snapshot",
        Request::Promote => "promote",
        Request::Shutdown => "shutdown",
    }
}

/// Per-model handles, interned under `model=<name>` labels when the
/// entry registers. Counters are monotone across drop/recreate of the
/// same model name (the registry interns by `(name, labels)`), which is
/// exactly what scrape consumers want from `_total` series.
pub struct ModelMetrics {
    pub predict_requests: Arc<Counter>,
    pub predict_rows: Arc<Counter>,
    pub predict_seconds: Arc<Histogram>,
    pub ingest_requests: Arc<Counter>,
    pub ingest_points: Arc<Counter>,
    pub ingest_seconds: Arc<Histogram>,
    pub step_requests: Arc<Counter>,
    pub step_rounds: Arc<Counter>,
    pub step_seconds: Arc<Histogram>,
    pub publishes: Arc<Counter>,
}

impl ModelMetrics {
    pub fn for_model(name: &str) -> ModelMetrics {
        let reg = obs::registry();
        let l: [(&str, &str); 1] = [("model", name)];
        ModelMetrics {
            predict_requests: reg.counter("nmbkm_model_predict_requests_total", &l),
            predict_rows: reg.counter("nmbkm_model_predict_rows_total", &l),
            predict_seconds: reg.histogram("nmbkm_model_predict_seconds", &l),
            ingest_requests: reg.counter("nmbkm_model_ingest_requests_total", &l),
            ingest_points: reg.counter("nmbkm_model_ingest_points_total", &l),
            ingest_seconds: reg.histogram("nmbkm_model_ingest_seconds", &l),
            step_requests: reg.counter("nmbkm_model_step_requests_total", &l),
            step_rounds: reg.counter("nmbkm_model_step_rounds_total", &l),
            step_seconds: reg.histogram("nmbkm_model_step_seconds", &l),
            publishes: reg.counter("nmbkm_model_publishes_total", &l),
        }
    }
}

/// One merged scrape: the global registry plus the polled sources that
/// keep their own atomics — the SIMD dispatch tally (`linalg::simd`
/// statics) and each model's transpose and exponion-neighbour caches
/// (lock-free `Arc` handles captured at entry registration; scrapes
/// never touch a session mutex).
pub fn samples(registry: &ModelRegistry) -> Vec<Sample> {
    let mut out = obs::registry().snapshot();
    for (tier, n) in simd::dispatch_tally() {
        out.push(Sample {
            name: "nmbkm_simd_dispatch_total".to_string(),
            labels: vec![("tier".to_string(), tier.to_string())],
            value: Value::Counter(n),
        });
    }
    for entry in registry.entries() {
        let mut cache = |engine: &str, hits: u64, builds: u64| {
            let labels = vec![
                ("engine".to_string(), engine.to_string()),
                ("model".to_string(), entry.name().to_string()),
            ];
            out.push(Sample {
                name: "nmbkm_trans_cache_hits_total".to_string(),
                labels: labels.clone(),
                value: Value::Counter(hits),
            });
            out.push(Sample {
                name: "nmbkm_trans_cache_builds_total".to_string(),
                labels,
                value: Value::Counter(builds),
            });
        };
        let (h, b) = entry.predict_cache_stats();
        cache("predict", h, b);
        if let Some((h, b)) = entry.session_cache_stats() {
            cache("session", h, b);
        }
        let mut neigh = |engine: &str, hits: u64, builds: u64, syncs: u64| {
            let labels = vec![
                ("engine".to_string(), engine.to_string()),
                ("model".to_string(), entry.name().to_string()),
            ];
            out.push(Sample {
                name: "nmbkm_neigh_cache_hits_total".to_string(),
                labels: labels.clone(),
                value: Value::Counter(hits),
            });
            out.push(Sample {
                name: "nmbkm_neigh_cache_builds_total".to_string(),
                labels: labels.clone(),
                value: Value::Counter(builds),
            });
            out.push(Sample {
                name: "nmbkm_neigh_cache_syncs_total".to_string(),
                labels,
                value: Value::Counter(syncs),
            });
        };
        if let Some((h, b, s)) = entry.predict_neigh_stats() {
            neigh("predict", h, b, s);
        }
        if let Some((h, b, s)) = entry.session_neigh_stats() {
            neigh("session", h, b, s);
        }
    }
    out
}

/// The `{"op":"metrics"}` response body: `{"schema":1,"metrics":[…]}`
/// over the merged sample set (the protocol layer adds `ok`/`op`).
pub fn metrics_json(registry: &ModelRegistry) -> Json {
    export::json_report(&samples(registry))
}

/// The `--metrics-addr` endpoint body: the same merged sample set in
/// Prometheus text exposition.
pub fn render_prometheus(registry: &ModelRegistry) -> String {
    export::prometheus(&samples(registry))
}
