//! Integration tests for follower replication (`serve::replica`): a
//! follower tailing a primary's WAL over real TCP converges to
//! byte-identical model state and byte-identical predict responses,
//! refuses local mutations until promoted, and — once promoted — fences
//! out the stale primary's epoch. A second test forces the snapshot
//! bootstrap path by truncating the primary's log behind checkpoints.

use nmbkm::config::{Algo, Rho, RunConfig};
use nmbkm::data::gaussian::GaussianMixture;
use nmbkm::data::Data;
use nmbkm::serve::protocol::{self, Request};
use nmbkm::serve::replica;
use nmbkm::serve::server::serve_listener_opts;
use nmbkm::serve::wal::{self, FsyncPolicy};
use nmbkm::serve::{ModelRegistry, SnapshotFormat, WireRow};
use nmbkm::util::json::{self, Json};
use std::fs;
use std::io::{BufRead, BufReader, Write};
use std::net::{TcpListener, TcpStream};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread;
use std::time::{Duration, Instant};

const NO_CKPT: u64 = u64::MAX;

fn cfg(k: usize, b0: usize) -> RunConfig {
    RunConfig {
        algo: Algo::TbRho,
        k,
        b0,
        rho: Rho::Infinite,
        threads: 2,
        seed: 11,
        max_rounds: 50,
        max_seconds: 60.0,
        eval_every_secs: 0.0,
        ..Default::default()
    }
}

fn tmpdir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir()
        .join(format!("nmbkm-replica-{tag}-{}", std::process::id()));
    let _ = fs::remove_dir_all(&d);
    d
}

fn rows(data: &Data, lo: usize, hi: usize) -> Vec<WireRow> {
    let mut row = vec![0f32; data.dim()];
    (lo..hi)
        .map(|i| {
            data.write_row_dense(i, &mut row);
            WireRow::Dense(row.clone())
        })
        .collect()
}

fn exec(reg: &ModelRegistry, req: &Request) -> Json {
    let (resp, _) = protocol::handle_request(reg, req);
    assert_eq!(
        resp.get("ok").and_then(Json::as_bool),
        Some(true),
        "request failed: {}",
        resp.to_string()
    );
    resp
}

fn ingest(reg: &ModelRegistry, name: &str, data: &Data, lo: usize, hi: usize, rounds: usize) {
    exec(
        reg,
        &Request::Ingest {
            model: Some(name.to_string()),
            points: rows(data, lo, hi),
            rounds,
            seconds: f64::INFINITY,
        },
    );
}

fn model_bytes(reg: &ModelRegistry, name: &str) -> String {
    reg.resolve(Some(name))
        .unwrap()
        .with_session(|s| Ok(s.snapshot(true)?.to_json().to_string()))
        .unwrap()
}

/// Primary (or follower) with an attached WAL, serving binary+JSONL on
/// an ephemeral port.
fn node(
    dir: &Path,
    ckpt_bytes: u64,
) -> (Arc<ModelRegistry>, Arc<wal::Wal>, String, thread::JoinHandle<anyhow::Result<()>>) {
    let reg = Arc::new(ModelRegistry::new());
    let rec = wal::recover(dir, FsyncPolicy::Always, ckpt_bytes, &reg).unwrap();
    reg.attach_wal(rec.wal.clone());
    let listener = TcpListener::bind("127.0.0.1:0").unwrap();
    let addr = listener.local_addr().unwrap().to_string();
    let server = {
        let r = reg.clone();
        thread::spawn(move || serve_listener_opts(r, listener, true))
    };
    (reg, rec.wal, addr, server)
}

/// One JSONL request/response round trip on a fresh connection.
fn jsonl(addr: &str, line: &str) -> String {
    let s = TcpStream::connect(addr).unwrap();
    s.set_read_timeout(Some(Duration::from_secs(20))).unwrap();
    s.set_write_timeout(Some(Duration::from_secs(20))).unwrap();
    let mut w = s.try_clone().unwrap();
    writeln!(w, "{line}").unwrap();
    let mut out = String::new();
    BufReader::new(s).read_line(&mut out).unwrap();
    out
}

fn predict_line(data: &Data, lo: usize, hi: usize) -> String {
    let mut row = vec![0f32; data.dim()];
    let pts: Vec<Json> = (lo..hi)
        .map(|i| {
            data.write_row_dense(i, &mut row);
            Json::Arr(row.iter().map(|&x| json::num(x as f64)).collect())
        })
        .collect();
    json::obj(vec![
        ("op", json::s("predict")),
        ("model", json::s("m1")),
        ("points", Json::Arr(pts)),
    ])
    .to_string()
}

fn wait_until(timeout: Duration, mut f: impl FnMut() -> bool) -> bool {
    let start = Instant::now();
    while start.elapsed() < timeout {
        if f() {
            return true;
        }
        thread::sleep(Duration::from_millis(50));
    }
    f()
}

/// Applied high-water equality: the follower has not just mirrored the
/// bytes (next_seq) but finished replaying them into the model.
fn caught_up(p: &ModelRegistry, f: &ModelRegistry, name: &str) -> bool {
    let ps = p.resolve(Some(name)).map(|e| e.last_seq()).unwrap_or(u64::MAX);
    let fseq = f.resolve(Some(name)).map(|e| e.last_seq()).unwrap_or(0);
    ps == fseq
}

#[test]
fn follower_mirrors_primary_and_promote_fences_old_epoch() {
    let data = GaussianMixture::default_spec(4, 6).generate(200, 9);
    let pdir = tmpdir("tail-prim");
    let fdir = tmpdir("tail-fol");

    let (preg, pwal, paddr, pserver) = node(&pdir, NO_CKPT);
    exec(
        &preg,
        &Request::Create { model: Some("m1".into()), dim: data.dim(), cfg: cfg(4, 16) },
    );
    ingest(&preg, "m1", &data, 0, 60, 2);
    ingest(&preg, "m1", &data, 60, 120, 2);

    let (freg, fwal, faddr, fserver) = node(&fdir, NO_CKPT);
    freg.set_follower(true);
    let stop = Arc::new(AtomicBool::new(false));
    let tail = replica::spawn_follower(freg.clone(), paddr.clone(), stop.clone());

    // catch up on the backlog
    assert!(
        wait_until(Duration::from_secs(30), || {
            fwal.next_seq() == pwal.next_seq() && caught_up(&preg, &freg, "m1")
        }),
        "follower never caught up with the backlog"
    );
    assert_eq!(
        model_bytes(&freg, "m1"),
        model_bytes(&preg, "m1"),
        "follower state must be byte-identical after bootstrap-free tailing"
    );

    // live tail: mutations land while the follower is connected
    ingest(&preg, "m1", &data, 120, 200, 3);
    exec(&preg, &Request::Step { model: Some("m1".into()), rounds: 1, seconds: f64::INFINITY });
    assert!(
        wait_until(Duration::from_secs(30), || {
            fwal.next_seq() == pwal.next_seq() && caught_up(&preg, &freg, "m1")
        }),
        "follower never caught up with live traffic"
    );
    assert_eq!(
        model_bytes(&freg, "m1"),
        model_bytes(&preg, "m1"),
        "follower state must stay byte-identical under live tailing"
    );

    // byte-identical predict responses over the wire
    let q = predict_line(&data, 0, 5);
    let from_primary = jsonl(&paddr, &q);
    let from_follower = jsonl(&faddr, &q);
    assert!(from_primary.contains("\"ok\":true"), "{from_primary}");
    assert_eq!(
        from_primary, from_follower,
        "predict responses must match byte-for-byte"
    );

    // a follower refuses local mutations
    let (resp, _) = protocol::handle_request(
        &freg,
        &Request::Step { model: Some("m1".into()), rounds: 1, seconds: f64::INFINITY },
    );
    assert_eq!(resp.get("ok").and_then(Json::as_bool), Some(false));
    assert!(
        resp.get("error")
            .and_then(Json::as_str)
            .unwrap_or("")
            .contains("read-only follower"),
        "unexpected refusal: {}",
        resp.to_string()
    );

    // promote over the wire: epoch bumps, the tail thread exits
    let old_epoch = pwal.epoch();
    let promoted = jsonl(&faddr, "{\"op\":\"promote\"}");
    assert_eq!(
        Json::parse(&promoted).unwrap().get("ok").and_then(Json::as_bool),
        Some(true),
        "{promoted}"
    );
    assert_eq!(fwal.epoch(), old_epoch + 1);
    stop.store(true, Ordering::SeqCst);
    tail.join().unwrap();

    // the stale primary's epoch is fenced out of the promoted node
    let rec = wal::encode_record(
        fwal.next_seq(),
        &json::obj(vec![
            ("op", json::s("step")),
            ("model", json::s("m1")),
            ("rounds", json::num(0.0)),
        ]),
        &[],
    );
    let err = fwal.append_raw(&rec, old_epoch).unwrap_err();
    assert!(
        format!("{err:#}").contains("stale primary"),
        "unexpected fence error: {err:#}"
    );

    // and the promoted node accepts mutations again
    exec(&freg, &Request::Step { model: Some("m1".into()), rounds: 1, seconds: f64::INFINITY });

    let _ = jsonl(&paddr, "{\"op\":\"shutdown\"}");
    let _ = jsonl(&faddr, "{\"op\":\"shutdown\"}");
    pserver.join().unwrap().unwrap();
    fserver.join().unwrap().unwrap();
    let _ = fs::remove_dir_all(&pdir);
    let _ = fs::remove_dir_all(&fdir);
}

#[test]
fn follower_bootstraps_when_primary_log_is_truncated() {
    let data = GaussianMixture::default_spec(4, 6).generate(130, 11);
    let pdir = tmpdir("boot-prim");
    let fdir = tmpdir("boot-fol");

    // 1-byte checkpoint threshold: the log is truncated behind a
    // checkpoint after every mutation, so a fresh follower cannot tail
    // from seq 1 — it must bootstrap from shipped snapshots
    let (preg, pwal, paddr, pserver) = node(&pdir, 1);
    // the primary serves binary-sidecar snapshot bodies: bootstrap must
    // sniff the format instead of assuming JSON
    preg.set_snapshot_format(SnapshotFormat::Binary);
    exec(
        &preg,
        &Request::Create { model: Some("m1".into()), dim: data.dim(), cfg: cfg(4, 16) },
    );
    ingest(&preg, "m1", &data, 0, 50, 2);
    ingest(&preg, "m1", &data, 50, 90, 2);
    exec(
        &preg,
        &Request::Create { model: Some("m2".into()), dim: data.dim(), cfg: cfg(2, 8) },
    );
    ingest(&preg, "m2", &data, 0, 30, 1);
    assert!(
        pwal.oldest_retained().unwrap() > 1,
        "primary log should be truncated behind checkpoints"
    );

    let (freg, fwal, faddr, fserver) = node(&fdir, NO_CKPT);
    freg.set_follower(true);
    let stop = Arc::new(AtomicBool::new(false));
    let tail = replica::spawn_follower(freg.clone(), paddr.clone(), stop.clone());

    assert!(
        wait_until(Duration::from_secs(30), || {
            fwal.next_seq() == pwal.next_seq()
                && caught_up(&preg, &freg, "m1")
                && caught_up(&preg, &freg, "m2")
        }),
        "follower never bootstrapped"
    );
    assert_eq!(model_bytes(&freg, "m1"), model_bytes(&preg, "m1"));
    assert_eq!(model_bytes(&freg, "m2"), model_bytes(&preg, "m2"));

    // ops keep flowing after the bootstrap; the follower stays in sync
    ingest(&preg, "m1", &data, 90, 130, 2);
    assert!(
        wait_until(Duration::from_secs(30), || {
            fwal.next_seq() == pwal.next_seq() && caught_up(&preg, &freg, "m1")
        }),
        "follower fell behind after bootstrap"
    );
    assert_eq!(model_bytes(&freg, "m1"), model_bytes(&preg, "m1"));

    stop.store(true, Ordering::SeqCst);
    tail.join().unwrap();
    let _ = jsonl(&paddr, "{\"op\":\"shutdown\"}");
    let _ = jsonl(&faddr, "{\"op\":\"shutdown\"}");
    pserver.join().unwrap().unwrap();
    fserver.join().unwrap().unwrap();
    let _ = fs::remove_dir_all(&pdir);
    let _ = fs::remove_dir_all(&fdir);
}
