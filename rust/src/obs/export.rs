//! Exposure formats for the metrics registry: a stable JSON schema
//! (the protocol's `{"op":"metrics"}` response body) and hand-rolled
//! Prometheus text exposition (the `--metrics-addr` endpoint), plus a
//! parser-validator for the exposition format so CI and tests can
//! assert well-formedness without a Prometheus binary.

use crate::obs::{estimated_sum_nanos, quantile_nanos, Histogram, Labels, Sample, Value};
use crate::util::json::{self, Json};
use std::collections::BTreeMap;
use std::fmt::Write as _;

/// One metric as a JSON object with a fixed key set per kind:
///
/// * counter/gauge: `{name, labels, type, value}`
/// * histogram: `{name, labels, type, count, p50_s, p90_s, p99_s,
///   sum_est_s, buckets}` where `buckets` lists the **non-empty**
///   buckets as `{le_s, count}` (per-bucket counts, not cumulative;
///   `le_s` is `null` for the open-ended last bucket).
pub fn sample_json(s: &Sample) -> Json {
    let labels = Json::Obj(
        s.labels
            .iter()
            .map(|(k, v)| (k.clone(), json::s(v)))
            .collect(),
    );
    match &s.value {
        Value::Counter(v) => json::obj(vec![
            ("name", json::s(&s.name)),
            ("labels", labels),
            ("type", json::s("counter")),
            ("value", json::num(*v as f64)),
        ]),
        Value::Gauge(v) => json::obj(vec![
            ("name", json::s(&s.name)),
            ("labels", labels),
            ("type", json::s("gauge")),
            ("value", json::num(*v as f64)),
        ]),
        Value::Histogram(buckets) => {
            let count: u64 = buckets.iter().sum();
            let rows: Vec<Json> = buckets
                .iter()
                .enumerate()
                .filter(|(_, &b)| b > 0)
                .map(|(i, &b)| {
                    json::obj(vec![
                        (
                            "le_s",
                            Histogram::le_nanos(i)
                                .map(|ns| json::num(ns as f64 / 1e9))
                                .unwrap_or(Json::Null),
                        ),
                        ("count", json::num(b as f64)),
                    ])
                })
                .collect();
            json::obj(vec![
                ("name", json::s(&s.name)),
                ("labels", labels),
                ("type", json::s("histogram")),
                ("count", json::num(count as f64)),
                ("p50_s", json::num(quantile_nanos(buckets, 0.50) as f64 / 1e9)),
                ("p90_s", json::num(quantile_nanos(buckets, 0.90) as f64 / 1e9)),
                ("p99_s", json::num(quantile_nanos(buckets, 0.99) as f64 / 1e9)),
                (
                    "sum_est_s",
                    json::num(estimated_sum_nanos(buckets) as f64 / 1e9),
                ),
                ("buckets", Json::Arr(rows)),
            ])
        }
    }
}

/// The full registry snapshot under the stable envelope consumers key
/// on: `{"schema": 1, "metrics": [...]}`.
pub fn json_report(samples: &[Sample]) -> Json {
    json::obj(vec![
        ("schema", json::num(1.0)),
        (
            "metrics",
            Json::Arr(samples.iter().map(sample_json).collect()),
        ),
    ])
}

fn escape_label(v: &str) -> String {
    let mut out = String::with_capacity(v.len());
    for c in v.chars() {
        match c {
            '\\' => out.push_str("\\\\"),
            '"' => out.push_str("\\\""),
            '\n' => out.push_str("\\n"),
            c => out.push(c),
        }
    }
    out
}

fn render_labels(labels: &Labels, extra: Option<(&str, &str)>) -> String {
    let mut parts: Vec<String> = labels
        .iter()
        .map(|(k, v)| format!("{k}=\"{}\"", escape_label(v)))
        .collect();
    if let Some((k, v)) = extra {
        parts.push(format!("{k}=\"{v}\""));
    }
    if parts.is_empty() {
        String::new()
    } else {
        format!("{{{}}}", parts.join(","))
    }
}

/// Prometheus text exposition (format 0.0.4) over a merged sample set.
/// Samples are re-sorted by `(name, labels)` so families stay
/// contiguous regardless of which source contributed them; one `# TYPE`
/// line precedes each family. Histogram families emit cumulative
/// `_bucket{le=…}` series ending at `+Inf`, an **estimated** `_sum`
/// (bucket midpoints — the record path spends its single `fetch_add`
/// on the bucket), and an exact `_count`.
pub fn prometheus(samples: &[Sample]) -> String {
    let mut ordered: Vec<&Sample> = samples.iter().collect();
    ordered.sort_by(|a, b| (&a.name, &a.labels).cmp(&(&b.name, &b.labels)));
    let mut out = String::new();
    let mut last_name: Option<&str> = None;
    for s in ordered {
        if last_name != Some(s.name.as_str()) {
            let kind = match s.value {
                Value::Counter(_) => "counter",
                Value::Gauge(_) => "gauge",
                Value::Histogram(_) => "histogram",
            };
            let _ = writeln!(out, "# TYPE {} {kind}", s.name);
            last_name = Some(s.name.as_str());
        }
        match &s.value {
            Value::Counter(v) => {
                let _ = writeln!(out, "{}{} {v}", s.name, render_labels(&s.labels, None));
            }
            Value::Gauge(v) => {
                let _ = writeln!(out, "{}{} {v}", s.name, render_labels(&s.labels, None));
            }
            Value::Histogram(buckets) => {
                let mut cum = 0u64;
                for (i, &b) in buckets.iter().enumerate() {
                    cum += b;
                    let le = match Histogram::le_nanos(i) {
                        Some(ns) => format!("{}", ns as f64 / 1e9),
                        None => "+Inf".to_string(),
                    };
                    let _ = writeln!(
                        out,
                        "{}_bucket{} {cum}",
                        s.name,
                        render_labels(&s.labels, Some(("le", &le)))
                    );
                }
                let _ = writeln!(
                    out,
                    "{}_sum{} {}",
                    s.name,
                    render_labels(&s.labels, None),
                    estimated_sum_nanos(buckets) as f64 / 1e9
                );
                let _ = writeln!(
                    out,
                    "{}_count{} {cum}",
                    s.name,
                    render_labels(&s.labels, None)
                );
            }
        }
    }
    out
}

/// Parse summary of a validated exposition body.
#[derive(Debug, PartialEq, Eq)]
pub struct ExpoSummary {
    /// `# TYPE` families declared.
    pub families: usize,
    /// Sample lines parsed.
    pub series: usize,
}

fn valid_name(name: &str) -> bool {
    let mut chars = name.chars();
    match chars.next() {
        Some(c) if c.is_ascii_alphabetic() || c == '_' || c == ':' => {}
        _ => return false,
    }
    chars.all(|c| c.is_ascii_alphanumeric() || c == '_' || c == ':')
}

/// Split `name{labels} value` into parts; labels keep their raw text.
fn split_sample_line(line: &str) -> Result<(String, Vec<(String, String)>, f64), String> {
    let (name, rest) = match line.find(['{', ' ']) {
        Some(i) => (line[..i].to_string(), &line[i..]),
        None => return Err(format!("no value on line {line:?}")),
    };
    if !valid_name(&name) {
        return Err(format!("bad metric name {name:?}"));
    }
    let (labels, value_str) = if let Some(rest) = rest.strip_prefix('{') {
        let close = rest
            .find('}')
            .ok_or_else(|| format!("unterminated labels on {line:?}"))?;
        let mut labels = Vec::new();
        let body = &rest[..close];
        if !body.is_empty() {
            for pair in body.split(',') {
                let (k, v) = pair
                    .split_once('=')
                    .ok_or_else(|| format!("bad label pair {pair:?}"))?;
                let v = v
                    .strip_prefix('"')
                    .and_then(|v| v.strip_suffix('"'))
                    .ok_or_else(|| format!("unquoted label value in {pair:?}"))?;
                if !valid_name(k) {
                    return Err(format!("bad label name {k:?}"));
                }
                labels.push((k.to_string(), v.to_string()));
            }
        }
        (labels, rest[close + 1..].trim())
    } else {
        (Vec::new(), rest.trim())
    };
    // a timestamp may follow the value; we never emit one but accept it
    let value_tok = value_str.split_whitespace().next().unwrap_or("");
    let value = match value_tok {
        "+Inf" => f64::INFINITY,
        "-Inf" => f64::NEG_INFINITY,
        "NaN" => f64::NAN,
        tok => tok
            .parse::<f64>()
            .map_err(|_| format!("bad sample value {tok:?} on {line:?}"))?,
    };
    Ok((name, labels, value))
}

/// Validate a Prometheus text exposition body. Checks: every sample
/// line parses (`name{labels} value`), every sampled family has a
/// preceding `# TYPE`, histogram `_bucket` series are cumulative
/// (non-decreasing in appearance order per label set), end at `+Inf`,
/// and agree with their `_count`. Returns the family/series tally so
/// callers can also assert non-emptiness.
pub fn validate_exposition(text: &str) -> Result<ExpoSummary, String> {
    let mut types: BTreeMap<String, String> = BTreeMap::new();
    let mut series = 0usize;
    // (family, labels-minus-le) -> (last cumulative, saw +Inf, inf value)
    type HistKey = (String, Vec<(String, String)>);
    let mut hist: BTreeMap<HistKey, (f64, bool, f64)> = BTreeMap::new();
    let mut counts: BTreeMap<HistKey, f64> = BTreeMap::new();
    for line in text.lines() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        if let Some(rest) = line.strip_prefix('#') {
            let rest = rest.trim();
            if let Some(decl) = rest.strip_prefix("TYPE ") {
                let mut it = decl.split_whitespace();
                let name = it.next().ok_or("empty TYPE line")?.to_string();
                let kind = it.next().ok_or("TYPE line without a kind")?.to_string();
                if !valid_name(&name) {
                    return Err(format!("bad TYPE name {name:?}"));
                }
                if !matches!(kind.as_str(), "counter" | "gauge" | "histogram" | "summary" | "untyped") {
                    return Err(format!("unknown TYPE kind {kind:?}"));
                }
                types.insert(name, kind);
            }
            continue; // HELP and plain comments pass through
        }
        let (name, labels, value) = split_sample_line(line)?;
        series += 1;
        // map histogram suffixes back to the declared family name
        let family = ["_bucket", "_sum", "_count"]
            .iter()
            .find_map(|suf| {
                name.strip_suffix(suf)
                    .filter(|base| types.get(*base).map(String::as_str) == Some("histogram"))
                    .map(str::to_string)
            })
            .unwrap_or_else(|| name.clone());
        if !types.contains_key(&family) {
            return Err(format!("sample {name:?} has no preceding # TYPE"));
        }
        if name.ends_with("_bucket") && types.get(&family).map(String::as_str) == Some("histogram")
        {
            let mut rest: Vec<(String, String)> = Vec::new();
            let mut le: Option<String> = None;
            for (k, v) in labels {
                if k == "le" {
                    le = Some(v);
                } else {
                    rest.push((k, v));
                }
            }
            let le = le.ok_or_else(|| format!("{name} series without le"))?;
            let slot = hist
                .entry((family.clone(), rest))
                .or_insert((0.0, false, 0.0));
            if value < slot.0 {
                return Err(format!(
                    "histogram {family} buckets not cumulative: {value} after {}",
                    slot.0
                ));
            }
            slot.0 = value;
            if le == "+Inf" {
                slot.1 = true;
                slot.2 = value;
            }
        } else if name.ends_with("_count")
            && types.get(&family).map(String::as_str) == Some("histogram")
        {
            counts.insert((family, labels), value);
        }
    }
    for ((family, labels), (_, saw_inf, inf_v)) in &hist {
        if !saw_inf {
            return Err(format!("histogram {family}{labels:?} missing +Inf bucket"));
        }
        match counts.get(&(family.clone(), labels.clone())) {
            Some(c) if c == inf_v => {}
            Some(c) => {
                return Err(format!(
                    "histogram {family}: +Inf bucket {inf_v} != _count {c}"
                ))
            }
            None => return Err(format!("histogram {family} missing _count")),
        }
    }
    Ok(ExpoSummary { families: types.len(), series })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::obs::HIST_BUCKETS;

    fn samples() -> Vec<Sample> {
        let h = Histogram::default();
        h.record_nanos(100);
        h.record_nanos(1 << 20);
        h.record_nanos(u64::MAX);
        vec![
            Sample {
                name: "nmbkm_requests_total".into(),
                labels: vec![("op".into(), "predict".into())],
                value: Value::Counter(42),
            },
            Sample {
                name: "nmbkm_requests_total".into(),
                labels: vec![("op".into(), "in\"ge\\st".into())],
                value: Value::Counter(7),
            },
            Sample {
                name: "nmbkm_pool_jobs_inflight".into(),
                labels: vec![],
                value: Value::Gauge(-2),
            },
            Sample {
                name: "nmbkm_request_seconds".into(),
                labels: vec![],
                value: Value::Histogram(h.snapshot()),
            },
        ]
    }

    #[test]
    fn exposition_round_trips_through_the_validator() {
        let text = prometheus(&samples());
        assert!(text.contains("# TYPE nmbkm_requests_total counter"));
        assert!(text.contains("nmbkm_requests_total{op=\"predict\"} 42"));
        assert!(text.contains("le=\"+Inf\"} 3"));
        assert!(text.contains("nmbkm_request_seconds_count 3"));
        let summary = validate_exposition(&text).unwrap();
        assert_eq!(summary.families, 3);
        // 2 counters + 1 gauge + (HIST_BUCKETS + sum + count)
        assert_eq!(summary.series, 3 + HIST_BUCKETS + 2);
    }

    #[test]
    fn validator_rejects_malformed_expositions() {
        assert!(validate_exposition("no_type_line 3\n").is_err());
        assert!(
            validate_exposition("# TYPE m counter\n9bad_name 3\n").is_err()
        );
        assert!(
            validate_exposition("# TYPE m counter\nm notanumber\n").is_err()
        );
        // non-cumulative buckets
        let bad = "# TYPE h histogram\nh_bucket{le=\"1\"} 5\n\
                   h_bucket{le=\"+Inf\"} 3\nh_count 3\n";
        assert!(validate_exposition(bad).is_err());
        // +Inf disagrees with _count
        let bad = "# TYPE h histogram\nh_bucket{le=\"+Inf\"} 3\nh_count 4\n";
        assert!(validate_exposition(bad).is_err());
        // missing +Inf
        let bad = "# TYPE h histogram\nh_bucket{le=\"1\"} 3\nh_count 3\n";
        assert!(validate_exposition(bad).is_err());
        // a correct minimal histogram passes
        let ok = "# TYPE h histogram\nh_bucket{le=\"1\"} 2\n\
                  h_bucket{le=\"+Inf\"} 3\nh_sum 1.5\nh_count 3\n";
        assert!(validate_exposition(ok).is_ok());
    }

    #[test]
    fn json_schema_keys_are_stable_per_kind() {
        for s in samples() {
            let j = sample_json(&s);
            let keys: Vec<&str> = match &j {
                Json::Obj(m) => m.keys().map(String::as_str).collect(),
                _ => panic!("sample_json must return an object"),
            };
            match &s.value {
                Value::Counter(_) | Value::Gauge(_) => {
                    assert_eq!(keys, vec!["labels", "name", "type", "value"]);
                }
                Value::Histogram(_) => {
                    assert_eq!(
                        keys,
                        vec![
                            "buckets", "count", "labels", "name", "p50_s",
                            "p90_s", "p99_s", "sum_est_s", "type"
                        ]
                    );
                }
            }
        }
        let rep = json_report(&samples());
        assert_eq!(rep.get("schema").unwrap().as_f64(), Some(1.0));
        assert_eq!(rep.get("metrics").unwrap().as_arr().unwrap().len(), 4);
        // round-trip through the serializer: valid JSON, stable order
        let reparsed = Json::parse(&rep.to_string()).unwrap();
        assert_eq!(reparsed.to_string(), rep.to_string());
    }
}
