//! Deterministic pseudo-random number generation.
//!
//! The offline image carries no `rand` crate, so we implement the two
//! generators the project needs ourselves:
//!
//! * [`SplitMix64`] — seed expansion / stream derivation (Steele et al.).
//! * [`Pcg64`] — PCG-XSH-RR 64/32 state with 128-bit LCG, the workhorse
//!   generator for shuffles, sampling and the dataset simulators.
//!
//! Every experiment derives independent named streams via
//! [`Pcg64::derive`], so adding a consumer of randomness in one module
//! never perturbs another module's stream (paper runs use 20 seeds; the
//! per-seed behaviour must be stable across refactors).

/// SplitMix64: tiny, full-period 2^64 generator used to expand seeds.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// PCG-XSH-RR with 128-bit state: fast, statistically solid, and — the
/// property we actually need — fully deterministic and portable.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
    /// cached second gaussian from Box–Muller
    gauss_spare: Option<f64>,
}

const PCG_MUL: u128 = 0x2360_ED05_1FC6_5DA4_4385_DF64_9FCC_F645;

impl Pcg64 {
    /// Construct from a seed and a stream id (any values are fine).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut sm = SplitMix64::new(seed ^ stream.rotate_left(32));
        let state = ((sm.next_u64() as u128) << 64) | sm.next_u64() as u128;
        let inc = (((sm.next_u64() as u128) << 64) | sm.next_u64() as u128) | 1;
        let mut rng = Self { state, inc, gauss_spare: None };
        rng.next_u64(); // decorrelate from the raw seed
        rng
    }

    /// Derive an independent child stream keyed by a label. Labels are
    /// hashed with FNV-1a so call sites read as `rng.derive("init")`.
    pub fn derive(&self, label: &str) -> Pcg64 {
        let mut h: u64 = 0xcbf2_9ce4_8422_2325;
        for b in label.bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x1000_0000_01b3);
        }
        Pcg64::new(self.state as u64 ^ (self.state >> 64) as u64, h)
    }

    /// PCG-XSL-RR 128/64 output function.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self
            .state
            .wrapping_mul(PCG_MUL)
            .wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, n) with Lemire rejection (unbiased).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.next_f64()
    }

    /// Standard normal via Box–Muller (caches the spare).
    pub fn gauss(&mut self) -> f64 {
        if let Some(s) = self.gauss_spare.take() {
            return s;
        }
        loop {
            let u = 2.0 * self.next_f64() - 1.0;
            let v = 2.0 * self.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.gauss_spare = Some(v * f);
                return u * f;
            }
        }
    }

    #[inline]
    pub fn gauss_f32(&mut self) -> f32 {
        self.gauss() as f32
    }

    /// Serialise the generator: `[state_hi, state_lo, inc_hi, inc_lo]`
    /// plus the cached Box–Muller spare. Together with [`from_parts`]
    /// this makes snapshots bit-exact: a resumed stream continues with
    /// precisely the values the paused one would have produced.
    ///
    /// [`from_parts`]: Pcg64::from_parts
    pub fn to_parts(&self) -> ([u64; 4], Option<f64>) {
        (
            [
                (self.state >> 64) as u64,
                self.state as u64,
                (self.inc >> 64) as u64,
                self.inc as u64,
            ],
            self.gauss_spare,
        )
    }

    /// Rebuild a generator from [`to_parts`] output.
    ///
    /// [`to_parts`]: Pcg64::to_parts
    pub fn from_parts(words: [u64; 4], gauss_spare: Option<f64>) -> Pcg64 {
        Pcg64 {
            state: ((words[0] as u128) << 64) | words[1] as u128,
            inc: ((words[2] as u128) << 64) | words[3] as u128,
            gauss_spare,
        }
    }

    /// In-place Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `m` distinct indices from [0, n) (partial Fisher–Yates
    /// when m is small relative to n, full shuffle otherwise).
    pub fn sample_distinct(&mut self, n: usize, m: usize) -> Vec<usize> {
        assert!(m <= n);
        if m * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(m);
            return all;
        }
        let mut seen = std::collections::HashSet::with_capacity(m * 2);
        let mut out = Vec::with_capacity(m);
        while out.len() < m {
            let x = self.below(n);
            if seen.insert(x) {
                out.push(x);
            }
        }
        out
    }

    /// Sample an index from unnormalised non-negative weights.
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        debug_assert!(total > 0.0);
        let mut t = self.next_f64() * total;
        for (i, &w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Zipf(s) sampler over {0, …, n−1} by inverse-CDF on a precomputed
/// table. Used by the RCV1 simulator's vocabulary draw, where the same
/// distribution is sampled millions of times.
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        Self { cdf }
    }

    pub fn sample(&self, rng: &mut Pcg64) -> usize {
        let u = rng.next_f64();
        self.cdf.partition_point(|&c| c < u).min(self.cdf.len() - 1)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Pcg64::new(1, 2);
        let mut b = Pcg64::new(1, 2);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_streams_differ() {
        let mut a = Pcg64::new(1, 2);
        let mut b = Pcg64::new(1, 3);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn derive_is_stable_and_independent() {
        let root = Pcg64::new(7, 0);
        let mut a1 = root.derive("alpha");
        let mut a2 = root.derive("alpha");
        let mut b = root.derive("beta");
        assert_eq!(a1.next_u64(), a2.next_u64());
        assert_ne!(a1.next_u64(), b.next_u64());
    }

    #[test]
    fn parts_roundtrip_continues_stream() {
        let mut rng = Pcg64::new(21, 9);
        rng.gauss(); // populate the spare so it is exercised too
        let (words, spare) = rng.to_parts();
        let mut copy = Pcg64::from_parts(words, spare);
        assert_eq!(rng.gauss().to_bits(), copy.gauss().to_bits());
        for _ in 0..50 {
            assert_eq!(rng.next_u64(), copy.next_u64());
        }
    }

    #[test]
    fn uniform_f64_moments() {
        let mut rng = Pcg64::new(42, 0);
        let n = 200_000;
        let mut sum = 0.0;
        let mut sq = 0.0;
        for _ in 0..n {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
            sq += x * x;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn gauss_moments() {
        let mut rng = Pcg64::new(43, 0);
        let n = 200_000;
        let (mut s1, mut s2, mut s3) = (0.0, 0.0, 0.0);
        for _ in 0..n {
            let x = rng.gauss();
            s1 += x;
            s2 += x * x;
            s3 += x * x * x;
        }
        assert!((s1 / n as f64).abs() < 1e-2);
        assert!((s2 / n as f64 - 1.0).abs() < 2e-2);
        assert!((s3 / n as f64).abs() < 5e-2);
    }

    #[test]
    fn below_is_unbiased_small_range() {
        let mut rng = Pcg64::new(5, 5);
        let mut counts = [0usize; 7];
        let n = 140_000;
        for _ in 0..n {
            counts[rng.below(7)] += 1;
        }
        for &c in &counts {
            let expect = n as f64 / 7.0;
            assert!((c as f64 - expect).abs() < expect * 0.05);
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg64::new(9, 1);
        let mut v: Vec<usize> = (0..1000).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..1000).collect::<Vec<_>>());
        assert_ne!(v, (0..1000).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_properties() {
        let mut rng = Pcg64::new(11, 0);
        for &(n, m) in &[(10usize, 10usize), (1000, 17), (50, 25)] {
            let s = rng.sample_distinct(n, m);
            assert_eq!(s.len(), m);
            let uniq: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(uniq.len(), m);
            assert!(s.iter().all(|&x| x < n));
        }
    }

    #[test]
    fn categorical_respects_weights() {
        let mut rng = Pcg64::new(13, 0);
        let w = [1.0, 0.0, 3.0];
        let mut counts = [0usize; 3];
        for _ in 0..40_000 {
            counts[rng.categorical(&w)] += 1;
        }
        assert_eq!(counts[1], 0);
        let ratio = counts[2] as f64 / counts[0] as f64;
        assert!((ratio - 3.0).abs() < 0.25, "ratio={ratio}");
    }

    #[test]
    fn zipf_is_monotone_decreasing_head() {
        let mut rng = Pcg64::new(17, 0);
        let z = Zipf::new(1000, 1.1);
        let mut counts = vec![0usize; 1000];
        for _ in 0..100_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[4]);
        assert!(counts[1] > counts[20]);
        assert!(counts[0] > 3 * counts[50]);
    }
}
