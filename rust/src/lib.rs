//! # nmbkm — Nested Mini-Batch K-Means
//!
//! A production-quality reproduction of *Nested Mini-Batch K-Means*
//! (Newling & Fleuret, NIPS 2016; arXiv preprint title: "Turbocharging
//! Mini-Batch K-Means") as a three-layer rust + JAX/Pallas stack:
//!
//! * **Layer 3 (this crate)** — the paper's coordination contribution:
//!   nested-batch state management, the `σ̂_C/p` batch-growth controller,
//!   triangle-inequality bound routing, exact sufficient-statistics
//!   maintenance, plus every baseline (`lloyd`, Elkan, Sculley `mb`,
//!   `sgd`) and every substrate (RNG, CLI, JSON, dense/CSR linear
//!   algebra, dataset simulators, threaded sharding, bench harness).
//! * **Layer 2/1 (build-time python)** — JAX graphs composing Pallas
//!   kernels, AOT-lowered to HLO text in `artifacts/`, executed from
//!   rust through the PJRT CPU client ([`runtime`]).
//!
//! Python never runs on the clustering path; after `make artifacts` the
//! rust binary is self-contained.
//!
//! On top of the reproduction sits the serving layer ([`serve`]):
//! versioned bit-exact model snapshots, pause/resume online training
//! sessions, and a JSONL ingest/predict/stats/snapshot protocol over
//! stdio or TCP (`nmbkm train --save` / `nmbkm serve` / `nmbkm
//! predict`).
//!
//! ## Quickstart
//!
//! ```no_run
//! use nmbkm::prelude::*;
//!
//! let data = nmbkm::data::gaussian::GaussianMixture::default_spec(8, 32)
//!     .generate(10_000, 42);
//! let cfg = RunConfig { k: 8, b0: 512, algo: Algo::TbRho,
//!                       rho: Rho::Infinite, ..RunConfig::default() };
//! let outcome = nmbkm::kmeans::run(&data, None, &cfg).unwrap();
//! println!("final training MSE: {}", outcome.final_mse);
//! ```

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod experiments;
pub mod kmeans;
pub mod linalg;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod util;

/// Commonly used items, re-exported for examples and binaries.
pub mod prelude {
    pub use crate::config::{Algo, Engine, Rho, RunConfig};
    pub use crate::data::{Data, Dataset};
    pub use crate::kmeans::metrics::RoundRecord;
    pub use crate::kmeans::{run, RunOutcome};
    pub use crate::serve::{OnlineSession, Snapshot};
    pub use crate::util::rng::Pcg64;
}
