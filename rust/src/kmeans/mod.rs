//! The k-means algorithm family (paper §2–3) and the unified run driver.
//!
//! | module             | algorithm | paper |
//! |--------------------|-----------|-------|
//! | [`lloyd`]           | exact Lloyd | §1 |
//! | [`elkan`]           | Lloyd + triangle-inequality bounds | §2.2 |
//! | [`sgd`]             | online k-means (b = 1) | Bottou–Bengio |
//! | [`minibatch`]       | Sculley mini-batch `mb` (Alg. 1 / 8) | §2.1, A.1 |
//! | [`minibatch_fixed`] | decontaminated `mb-f` (Alg. 4) | §3.1 |
//! | [`growbatch`]       | nested grow-batch `gb-ρ` (Alg. 7 / 10) | §3.2–3.3 |
//! | [`turbobatch`]      | turbocharged `tb-ρ` (Alg. 9 / 11) | §3.3.3 |
//!
//! All algorithms implement [`Clusterer`] — one `round()` per paper
//! round — and are executed by [`run`], which owns the work clock, the
//! validation-MSE protocol and trace recording.

pub mod assign;
pub mod bounds;
pub mod controller;
pub mod elkan;
pub mod growbatch;
pub mod init;
pub mod lloyd;
pub mod metrics;
pub mod minibatch;
pub mod minibatch_fixed;
pub mod sgd;
pub mod state;
pub mod turbobatch;

use crate::config::{Algo, Engine, RunConfig};
use crate::coordinator::merge::fold;
use crate::coordinator::shard::{chunk_ranges, Pool};
use crate::data::{shuffle, Data};
use crate::kmeans::assign::{AssignEngine, NativeEngine, Sel};
use crate::kmeans::metrics::{RoundRecord, Trace};
use crate::kmeans::state::{Assignments, Centroids, SuffStats};
use crate::util::rng::Pcg64;
use crate::util::timer::WorkClock;

/// Per-round execution context handed to algorithms.
pub struct Ctx<'a> {
    pub data: &'a Data,
    pub engine: &'a dyn AssignEngine,
    pub pool: Pool,
    pub rng: Pcg64,
}

/// What one round did (for the trace).
#[derive(Clone, Copy, Debug, Default)]
pub struct RoundInfo {
    pub dist_calcs: u64,
    pub bound_skips: u64,
    pub changed: u64,
    pub batch: usize,
    pub train_mse: f64,
}

/// The complete mid-run state of a nested-batch algorithm — everything
/// needed to pause training, serialise it (`serve::snapshot`), and
/// resume bit-exactly: centroids with their cached norms/displacements,
/// the exact sufficient statistics, per-point assignments over the data
/// buffer, and the batch cursor `(b_prev, b)`.
///
/// Elkan bounds are deliberately *not* part of the state: zeroed lower
/// bounds are always valid, so a resumed `tb-ρ` re-tightens them during
/// its first round at the cost of extra distance computations while
/// producing the identical assignment sequence (ties break by strict
/// improvement in both the bounded and the exhaustive scan).
#[derive(Clone, Debug)]
pub struct NestedState {
    pub cent: Centroids,
    pub stats: SuffStats,
    pub assign: Assignments,
    /// b_o: points already counted into the statistics (prefix length).
    pub b_prev: usize,
    /// b: active batch size for the next round.
    pub b: usize,
    /// Total points in the backing data buffer.
    pub n: usize,
}

/// One paper-round of an algorithm.
pub trait Clusterer {
    fn round(&mut self, ctx: &mut Ctx) -> RoundInfo;
    fn centroids(&self) -> &Centroids;
    /// Reached a fixed point (full-batch algorithms only).
    fn converged(&self) -> bool {
        false
    }
    fn name(&self) -> String;
    /// Export the resumable state (`gb-ρ`/`tb-ρ` only — the nested
    /// invariant is what makes mid-run state well-defined).
    fn export_state(&self) -> Option<NestedState> {
        None
    }
    /// Grow the backing data buffer to `new_n` points. The appended
    /// points are unseen: they join the active batch when the growth
    /// controller votes to expand past them, so each still enters the
    /// statistics exactly once (§3.1). Returns false for algorithms
    /// without online-ingest support.
    fn extend_data(&mut self, new_n: usize) -> bool {
        let _ = new_n;
        false
    }
}

/// Build per-shard `SuffStats` deltas for newly assigned points
/// (`add_point`) in parallel and fold them.
pub fn par_add_stats(
    data: &Data,
    sel: Sel,
    lbl: &[u32],
    d2: &[f32],
    k: usize,
    pool: &Pool,
) -> SuffStats {
    let n = sel.len();
    let ranges = chunk_ranges(n, pool.threads, 1024);
    let parts = pool.run_chunks(n, 1024, |ci, _| {
        let r = &ranges[ci];
        let mut delta = SuffStats::zeros(k, data.dim());
        for t in r.clone() {
            delta.add_point(data, sel.nth(t), lbl[t], d2[t]);
        }
        delta
    });
    fold(parts).unwrap_or_else(|| SuffStats::zeros(k, data.dim()))
}

/// Parallel reassignment deltas (`reassign_point` semantics) for seen
/// points; returns (delta, changed count).
pub fn par_reassign_stats(
    data: &Data,
    sel: Sel,
    old_lbl: &[u32],
    new_lbl: &[u32],
    new_d2: &[f32],
    k: usize,
    pool: &Pool,
) -> (SuffStats, u64) {
    let n = sel.len();
    let ranges = chunk_ranges(n, pool.threads, 1024);
    let parts = pool.run_chunks(n, 1024, |ci, _| {
        let r = &ranges[ci];
        let mut delta = SuffStats::zeros(k, data.dim());
        let mut changed = 0u64;
        for t in r.clone() {
            let i = sel.nth(t);
            delta.reassign_point(data, i, old_lbl[t], new_lbl[t], new_d2[t]);
            changed += u64::from(old_lbl[t] != new_lbl[t]);
        }
        (delta, changed)
    });
    let mut total = SuffStats::zeros(k, data.dim());
    let mut changed = 0;
    for (d, c) in parts {
        crate::coordinator::merge::Mergeable::merge(&mut total, d);
        changed += c;
    }
    (total, changed)
}

/// Outcome of a [`run`].
#[derive(Debug)]
pub struct RunOutcome {
    pub trace: Trace,
    /// Final validation MSE (falls back to the training proxy when no
    /// validation set was given).
    pub final_mse: f64,
    pub centroids: Centroids,
    pub rounds: usize,
    /// Total work seconds (validation excluded, paper protocol).
    pub work_secs: f64,
}

/// Instantiate the configured algorithm over (pre-shuffled) data.
pub fn make_clusterer(
    data: &Data,
    cfg: &RunConfig,
) -> Box<dyn Clusterer + Send> {
    let cent = match cfg.init {
        crate::config::InitScheme::FirstK => init::first_k(data, cfg.k),
        crate::config::InitScheme::Uniform => {
            let mut rng = Pcg64::new(cfg.seed, 0x1217).derive("init-uniform");
            init::uniform(data, cfg.k, &mut rng)
        }
        crate::config::InitScheme::KmeansPPBatch => {
            // D² seeding over the initial batch only — needs no full
            // pass, so it is mini-batch compatible (paper §5)
            let b = cfg.b0.min(data.n()).max(cfg.k);
            let head = data.slice(0, b);
            let mut rng = Pcg64::new(cfg.seed, 0x1217).derive("init-pp");
            init::kmeanspp(&head, cfg.k, &mut rng)
        }
    };
    let n = data.n();
    let b0 = cfg.b0.min(n).max(1);
    match cfg.algo {
        Algo::Lloyd => Box::new(lloyd::Lloyd::new(cent, n)),
        Algo::Elkan => Box::new(elkan::Elkan::new(cent, n)),
        Algo::Sgd => Box::new(sgd::Sgd::new(cent, b0)),
        Algo::Mb => Box::new(minibatch::MiniBatch::new(
            cent,
            n,
            b0,
            minibatch::Formulation::Alg8,
        )),
        Algo::MbF => Box::new(minibatch_fixed::MiniBatchFixed::new(cent, n, b0)),
        Algo::GbRho => Box::new(growbatch::GrowBatch::new(cent, n, b0, cfg.rho)),
        Algo::TbRho => Box::new(turbobatch::TurboBatch::new(
            cent,
            n,
            b0,
            cfg.rho,
            cfg.engine == Engine::Xla,
        )),
    }
}

/// Rebuild the configured algorithm around previously exported state
/// (see [`Clusterer::export_state`] / `serve::snapshot`). Only the
/// nested-batch algorithms are resumable.
pub fn resume_clusterer(
    state: NestedState,
    cfg: &RunConfig,
) -> anyhow::Result<Box<dyn Clusterer + Send>> {
    anyhow::ensure!(
        state.cent.k() == cfg.k,
        "state has k={} but config says k={}",
        state.cent.k(),
        cfg.k
    );
    match cfg.algo {
        Algo::GbRho => Ok(Box::new(growbatch::GrowBatch::resume(state, cfg.rho))),
        Algo::TbRho => Ok(Box::new(turbobatch::TurboBatch::resume(
            state,
            cfg.rho,
            cfg.engine == Engine::Xla,
        ))),
        other => anyhow::bail!(
            "algorithm '{}' is not resumable (only gb-ρ / tb-ρ keep \
             well-defined nested-batch state)",
            other.name()
        ),
    }
}

/// Run one configured clustering job end to end: shuffle per seed,
/// initialise with the first k points (paper §4.3 protocol), iterate
/// rounds under the work clock, score validation MSE off-clock.
pub fn run(
    train: &Data,
    val: Option<&Data>,
    cfg: &RunConfig,
) -> anyhow::Result<RunOutcome> {
    let data = shuffle::shuffled(train, cfg.seed);
    let engine: Box<dyn AssignEngine + Send> = match cfg.engine {
        Engine::Native => Box::new(NativeEngine::default()),
        Engine::Xla => crate::runtime::make_engine(&cfg.artifacts_dir)?,
    };
    run_prepared(&data, val, cfg, engine.as_ref())
}

/// [`run`] over already-shuffled data with a caller-supplied engine
/// (used by experiments to share one PJRT client across runs).
pub fn run_prepared(
    data: &Data,
    val: Option<&Data>,
    cfg: &RunConfig,
    engine: &dyn AssignEngine,
) -> anyhow::Result<RunOutcome> {
    anyhow::ensure!(cfg.k >= 1 && cfg.k <= data.n(), "bad k={}", cfg.k);
    let pool = Pool::new(cfg.threads);
    let mut alg = make_clusterer(data, cfg);
    let mut ctx = Ctx {
        data,
        engine,
        pool: pool.clone(),
        rng: Pcg64::new(cfg.seed, 0xA160).derive(&cfg.label()),
    };
    let mut clock = WorkClock::new();
    let mut trace = Trace {
        algo: cfg.label(),
        dataset: String::new(),
        seed: cfg.seed,
        records: vec![],
    };
    let mut last_eval = -f64::INFINITY;
    let mut rounds = 0usize;
    loop {
        clock.start();
        let info = alg.round(&mut ctx);
        clock.pause();
        let t = clock.elapsed_secs();
        let stop = t >= cfg.max_seconds
            || rounds + 1 >= cfg.max_rounds
            || (cfg.stop_on_convergence && alg.converged());
        let mut val_mse = None;
        if let Some(v) = val {
            if t - last_eval >= cfg.eval_every_secs || stop || rounds == 0 {
                let cent = alg.centroids();
                val_mse = Some(clock.off_clock(|| {
                    assign::validation_mse(v, cent, engine, &pool)
                }));
                last_eval = t;
            }
        }
        trace.push(RoundRecord {
            round: rounds,
            t_work: t,
            batch: info.batch,
            dist_calcs: info.dist_calcs,
            bound_skips: info.bound_skips,
            changed: info.changed,
            val_mse,
            train_mse: info.train_mse,
        });
        rounds += 1;
        if stop {
            break;
        }
    }
    let final_mse = trace
        .final_val_mse()
        .unwrap_or_else(|| trace.records.last().map(|r| r.train_mse).unwrap_or(f64::NAN));
    let centroids = alg.centroids().clone();
    Ok(RunOutcome {
        trace,
        final_mse,
        centroids,
        rounds,
        work_secs: clock.elapsed_secs(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Rho;
    use crate::data::gaussian::GaussianMixture;

    #[test]
    fn par_stats_match_serial() {
        let data = GaussianMixture::default_spec(4, 6).generate(500, 1);
        let cent = init::first_k(&data, 4);
        let eng = NativeEngine::default();
        let pool = Pool::new(4);
        let mut lbl = vec![0u32; 500];
        let mut d2 = vec![0f32; 500];
        eng.assign(&data, Sel::Range(0, 500), &cent, &pool, &mut lbl, &mut d2);
        let par = par_add_stats(&data, Sel::Range(0, 500), &lbl, &d2, 4, &pool);
        let ser = SuffStats::rebuild(&data, 4, 0..500, &lbl, &d2);
        assert!(par.max_abs_diff(&ser) < 1e-9);
    }

    #[test]
    fn run_all_algorithms_reduce_mse() {
        let ds = GaussianMixture::default_spec(5, 8).dataset(2000, 400, 9);
        for algo in [
            Algo::Lloyd,
            Algo::Elkan,
            Algo::Sgd,
            Algo::Mb,
            Algo::MbF,
            Algo::GbRho,
            Algo::TbRho,
        ] {
            let cfg = RunConfig {
                algo,
                k: 5,
                b0: 128,
                rho: Rho::Infinite,
                max_seconds: 2.0,
                max_rounds: 60,
                seed: 1,
                threads: 2,
                ..Default::default()
            };
            let out = run(&ds.train, Some(&ds.val), &cfg).unwrap();
            let first = out.trace.records[0].val_mse.unwrap();
            let last = out.final_mse;
            // validation MSE is not guaranteed monotone; after the
            // budget it must not be meaningfully worse
            assert!(
                last <= first * 1.10,
                "{algo:?}: mse went {first} -> {last}"
            );
            assert!(out.rounds >= 1);
        }
    }

    #[test]
    fn run_is_deterministic_per_seed() {
        let ds = GaussianMixture::default_spec(3, 4).dataset(600, 100, 2);
        let cfg = RunConfig {
            algo: Algo::TbRho,
            k: 3,
            b0: 64,
            max_rounds: 3,
            max_seconds: 30.0,
            seed: 7,
            threads: 4,
            eval_every_secs: 0.0,
            ..Default::default()
        };
        let a = run(&ds.train, Some(&ds.val), &cfg).unwrap();
        let b = run(&ds.train, Some(&ds.val), &cfg).unwrap();
        assert_eq!(a.rounds, b.rounds);
        assert_eq!(a.centroids.c.data, b.centroids.c.data);
        // different seed ⇒ different trajectory
        let cfg2 = RunConfig { seed: 8, ..cfg };
        let c = run(&ds.train, Some(&ds.val), &cfg2).unwrap();
        assert_ne!(a.centroids.c.data, c.centroids.c.data);
    }
}
