//! Bench P — §Perf micro-benchmarks over the hot paths the profiles
//! identified: dense/sparse distance kernels (scalar reference vs the
//! runtime-dispatched SIMD tier), the bound screen, the tb point-step,
//! stats merging, and engine-level assignment throughput. Emits a
//! machine-readable `BENCH_micro.json` (override with `--json PATH`)
//! so the perf trajectory is tracked per commit; `--simd
//! scalar|sse2|avx2|fma` forces a dispatch tier and `--smoke` runs one
//! iteration of everything (CI).

use nmbkm::bench::{BenchOpts, BenchReport, BenchSet};
use nmbkm::coordinator::Pool;
use nmbkm::data::{gaussian::GaussianMixture, infmnist::InfMnist, rcv1::Rcv1Sim, Storage};
use nmbkm::kmeans::assign::{AssignEngine, NativeEngine, Sel, Strategy};
use nmbkm::kmeans::{bounds, init};
use nmbkm::linalg::neighbours::NeighbourRows;
use nmbkm::linalg::simd::{self, Tier};
use nmbkm::linalg::sparse::{spdot, TransposedCentroids};
use nmbkm::util::json;

fn arg_value(args: &[String], flag: &str) -> Option<String> {
    args.iter()
        .position(|a| a == flag)
        .and_then(|i| args.get(i + 1))
        .cloned()
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = BenchOpts::from_env_or_args(&args);
    let threads = std::thread::available_parallelism().map(|x| x.get()).unwrap_or(4);
    let json_path =
        arg_value(&args, "--json").unwrap_or_else(|| "BENCH_micro.json".to_string());
    if let Some(req) = arg_value(&args, "--simd") {
        simd::force_tier(Some(simd::detect(Some(&req), None)));
    }
    let active = simd::tier();
    println!(
        "dispatch tier: {} (available: {})",
        active.name(),
        simd::available_tiers()
            .iter()
            .map(|t| t.name())
            .collect::<Vec<_>>()
            .join(", ")
    );

    let mut report = BenchReport::new("micro_hotpaths");
    report.meta("tier", json::s(active.name()));
    report.meta("threads", json::num(threads as f64));
    report.meta("arch", json::s(std::env::consts::ARCH));
    report.meta("warmup", json::num(opts.warmup as f64));
    report.meta("samples", json::num(opts.samples as f64));

    // --- raw kernels -----------------------------------------------------
    let mut set = BenchSet::new("kernels", opts);
    let a: Vec<f32> = (0..784).map(|i| (i as f32).sin()).collect();
    let b: Vec<f32> = (0..784).map(|i| (i as f32).cos()).collect();
    set.bench("dot d=784 x100k (scalar)", || {
        let mut acc = 0f32;
        for _ in 0..100_000 {
            acc += simd::dot_with(
                Tier::Scalar,
                std::hint::black_box(&a),
                std::hint::black_box(&b),
            );
        }
        acc
    });
    set.bench("dot d=784 x100k (simd)", || {
        let mut acc = 0f32;
        for _ in 0..100_000 {
            acc += simd::dot(std::hint::black_box(&a), std::hint::black_box(&b));
        }
        acc
    });
    // memory-roofline context: 2 vectors × 784 × 4B × 100k = 627 MB read
    let m = set.get("dot d=784 x100k (simd)").unwrap().min_secs();
    println!(
        "     → {:.2} GFLOP/s, {:.2} GB/s effective",
        2.0 * 784.0 * 100_000.0 / m / 1e9,
        2.0 * 784.0 * 4.0 * 100_000.0 / m / 1e9
    );
    let c4: Vec<f32> = (0..4 * 784).map(|i| (i as f32 * 0.37).cos()).collect();
    let rows4: Vec<&[f32]> = (0..4).map(|j| &c4[j * 784..(j + 1) * 784]).collect();
    set.bench("dot4 d=784 x25k (scalar)", || {
        let mut acc = [0f32; 4];
        for _ in 0..25_000 {
            let d = simd::dot4_with(
                Tier::Scalar,
                std::hint::black_box(&a),
                rows4[0],
                rows4[1],
                rows4[2],
                rows4[3],
            );
            for j in 0..4 {
                acc[j] += d[j];
            }
        }
        acc
    });
    set.bench("dot4 d=784 x25k (simd)", || {
        let mut acc = [0f32; 4];
        for _ in 0..25_000 {
            let d = simd::dot4(
                std::hint::black_box(&a),
                rows4[0],
                rows4[1],
                rows4[2],
                rows4[3],
            );
            for j in 0..4 {
                acc[j] += d[j];
            }
        }
        acc
    });
    let dot_scalar_s = set.get("dot d=784 x100k (scalar)").unwrap().min_secs();
    let dot_simd_s = set.get("dot d=784 x100k (simd)").unwrap().min_secs();
    println!("     → dot speedup {:.2}x over scalar", dot_scalar_s / dot_simd_s);
    report.meta("speedup_dot_d784", json::num(dot_scalar_s / dot_simd_s));
    report.push(set);

    // --- engine assignment throughput -------------------------------------
    let data = InfMnist::default().generate(20_000, 1);
    let cent = init::first_k(&data, 50);
    let eng = NativeEngine::default();
    let mut lbl = vec![0u32; data.n()];
    let mut d2 = vec![0f32; data.n()];
    let mut set = BenchSet::new("assign dense 20k x 784, k=50", opts);
    simd::force_tier(Some(Tier::Scalar));
    set.bench("native 1 thread (scalar)", || {
        eng.assign(&data, Sel::Range(0, data.n()), &cent, &Pool::new(1), &mut lbl, &mut d2)
    });
    simd::force_tier(Some(active));
    set.bench("native 1 thread (simd)", || {
        eng.assign(&data, Sel::Range(0, data.n()), &cent, &Pool::new(1), &mut lbl, &mut d2)
    });
    let pool_n = Pool::new(threads);
    if threads > 1 {
        set.bench(&format!("native {threads} threads (simd)"), || {
            eng.assign(&data, Sel::Range(0, data.n()), &cent, &pool_n, &mut lbl, &mut d2)
        });
    }
    if let Ok(xla) = nmbkm::runtime::make_engine("artifacts") {
        set.bench("xla engine (PJRT tiles)", || {
            xla.assign(&data, Sel::Range(0, data.n()), &cent, &pool_n, &mut lbl, &mut d2)
        });
    } else {
        println!("  (xla engine skipped: run `make artifacts`)");
    }
    let t_scalar = set.get("native 1 thread (scalar)").unwrap().min_secs();
    let t1 = set.get("native 1 thread (simd)").unwrap().min_secs();
    println!("     → assignment speedup {:.2}x over scalar", t_scalar / t1);
    report.meta("speedup_assign_dense_1t", json::num(t_scalar / t1));
    if threads > 1 {
        let tn = set
            .get(&format!("native {threads} threads (simd)"))
            .unwrap()
            .min_secs();
        println!("     → thread scaling {:.2}x on {threads} threads", t1 / tn);
        report.meta("thread_scaling", json::num(t1 / tn));
    }
    report.push(set);

    // --- sparse engine -----------------------------------------------------
    let sdata = Rcv1Sim::default().generate(20_000, 2);
    let scent = init::first_k(&sdata, 50);
    let mut slbl = vec![0u32; sdata.n()];
    let mut sd2 = vec![0f32; sdata.n()];
    let mut set = BenchSet::new("assign sparse 20k x 47k, k=50", opts);
    set.bench("native 1 thread", || {
        eng.assign(&sdata, Sel::Range(0, sdata.n()), &scent, &Pool::new(1), &mut slbl, &mut sd2)
    });
    if threads > 1 {
        set.bench(&format!("native {threads} threads"), || {
            eng.assign(&sdata, Sel::Range(0, sdata.n()), &scent, &pool_n, &mut slbl, &mut sd2)
        });
    }
    report.push(set);

    // --- sparse kernels (the fig. 3 RCV1 shape: k=64, ~76 nnz/row) --------
    // the acceptance comparison for the sparse hot-path overhaul: the
    // dispatched AXPY sweep and the blocked+pruned engine path against
    // the scalar tc.dots reference, under both forced-scalar and auto
    // dispatch in CI
    let skdata = Rcv1Sim::default().generate(4_000, 7);
    let skcent = init::first_k(&skdata, 64);
    let tc = TransposedCentroids::build(&skcent.c);
    let sm = match &skdata.storage {
        Storage::Sparse(m) => m,
        _ => unreachable!("rcv1 sim generates CSR data"),
    };
    let mut set = BenchSet::new("sparse kernels (rcv1 4k rows, k=64)", opts);
    set.bench("spdot row pass (gather)", || {
        let mut acc = 0f32;
        for i in 0..sm.rows {
            let (idx, vals) = sm.row(i);
            acc += spdot(
                std::hint::black_box(idx),
                std::hint::black_box(vals),
                skcent.c.row(i % 64),
            );
        }
        acc
    });
    let mut dots_a = vec![0f32; 64];
    set.bench("tc.dots pass (scalar)", || {
        let mut acc = 0f32;
        for i in 0..sm.rows {
            let (idx, vals) = sm.row(i);
            tc.dots_with(Tier::Scalar, idx, vals, &mut dots_a);
            acc += dots_a[0];
        }
        acc
    });
    let mut dots_b = vec![0f32; 64];
    set.bench("tc.dots pass (simd)", || {
        let mut acc = 0f32;
        for i in 0..sm.rows {
            let (idx, vals) = sm.row(i);
            tc.dots_with(active, idx, vals, &mut dots_b);
            acc += dots_b[0];
        }
        acc
    });
    let mut rb = TransposedCentroids::build(&skcent.c);
    set.bench("transpose rebuild k=64 d=47k (in place)", || {
        rb.rebuild(&skcent.c);
        rb.ct[0]
    });
    let dots_scalar_s = set.get("tc.dots pass (scalar)").unwrap().min_secs();
    let dots_simd_s = set.get("tc.dots pass (simd)").unwrap().min_secs();
    println!(
        "     → tc.dots speedup {:.2}x over scalar",
        dots_scalar_s / dots_simd_s
    );
    report.meta("speedup_tc_dots_k64", json::num(dots_scalar_s / dots_simd_s));
    report.push(set);

    // --- blocked + pruned sparse assignment (k=64) -------------------------
    let mut set = BenchSet::new("assign sparse blocked (rcv1 4k rows, k=64)", opts);
    let beng = NativeEngine::default();
    let mut bl = vec![0u32; skdata.n()];
    let mut bd = vec![0f32; skdata.n()];
    simd::force_tier(Some(Tier::Scalar));
    set.bench("blocked+pruned 1 thread (scalar)", || {
        beng.assign(
            &skdata,
            Sel::Range(0, skdata.n()),
            &skcent,
            &Pool::new(1),
            &mut bl,
            &mut bd,
        )
    });
    simd::force_tier(Some(active));
    set.bench("blocked+pruned 1 thread (simd)", || {
        beng.assign(
            &skdata,
            Sel::Range(0, skdata.n()),
            &skcent,
            &Pool::new(1),
            &mut bl,
            &mut bd,
        )
    });
    if threads > 1 {
        set.bench(&format!("blocked+pruned {threads} threads (simd)"), || {
            beng.assign(
                &skdata,
                Sel::Range(0, skdata.n()),
                &skcent,
                &pool_n,
                &mut bl,
                &mut bd,
            )
        });
    }
    let bs = set.get("blocked+pruned 1 thread (scalar)").unwrap().min_secs();
    let bi = set.get("blocked+pruned 1 thread (simd)").unwrap().min_secs();
    println!(
        "     → sparse assignment speedup {:.2}x over scalar (k=64)",
        bs / bi
    );
    report.meta("speedup_assign_sparse_k64_1t", json::num(bs / bi));
    report.push(set);

    // --- serving-scale k: exponion pruning (dense, k=4096) -----------------
    // the acceptance comparison for the exponion engine: same mixture,
    // disjoint point/centroid draws so no point sits exactly on a
    // centroid, forced-Flat vs Auto (which resolves to exponion at this
    // k). Wall-clock speedup AND the counter-backed dist-calc reduction
    // both go into the report meta — the counters are the trend gate,
    // wall clock is context.
    let kbig = 4096usize;
    let xspec = GaussianMixture::default_spec(256, 64);
    let xdata = xspec.generate(4_096, 11);
    let xcent = init::first_k(&xspec.generate(kbig, 12), kbig);
    let mut set = BenchSet::new("assign dense serving-scale (4k pts, k=4096)", opts);
    let flat_eng = NativeEngine::default().with_strategy(Strategy::Flat);
    let exp_eng = NativeEngine::default().with_strategy(Strategy::Auto);
    let mut xl = vec![0u32; xdata.n()];
    let mut xd = vec![0f32; xdata.n()];
    set.bench("flat scan 1 thread", || {
        flat_eng.assign(&xdata, Sel::Range(0, xdata.n()), &xcent, &Pool::new(1), &mut xl, &mut xd)
    });
    set.bench("exponion 1 thread", || {
        exp_eng.assign(&xdata, Sel::Range(0, xdata.n()), &xcent, &Pool::new(1), &mut xl, &mut xd)
    });
    if threads > 1 {
        set.bench(&format!("exponion {threads} threads"), || {
            exp_eng.assign(&xdata, Sel::Range(0, xdata.n()), &xcent, &pool_n, &mut xl, &mut xd)
        });
    }
    set.bench("neighbour rows build k=4096 d=64", || {
        NeighbourRows::build(active, &xcent.c).nn_mean
    });
    let t_flat = set.get("flat scan 1 thread").unwrap().min_secs();
    let t_exp = set.get("exponion 1 thread").unwrap().min_secs();
    let (ep, ee) = exp_eng.strategy_tally().snapshot()[2];
    let dense_reduction = if ee > 0 { ep as f64 * kbig as f64 / ee as f64 } else { 1.0 };
    println!(
        "     → exponion {:.2}x wall clock, {:.1}x fewer distance calcs (k=4096)",
        t_flat / t_exp,
        dense_reduction
    );
    report.meta("speedup_assign_dense_k4096", json::num(t_flat / t_exp));
    report.meta("calc_reduction_dense_k4096", json::num(dense_reduction));
    report.push(set);

    // --- serving-scale k: sparse strategy shoot-out (k=1024) ---------------
    // the three strategies side by side on a CSR corpus whose vocab is
    // under EXPONION_SPARSE_MAX_D, so Auto resolves to exponion
    let svc = Rcv1Sim { vocab: 2_000, topic_vocab: 400, ..Rcv1Sim::default() };
    let ksp = 1024usize;
    let ysdata = svc.generate(6_000, 13);
    let yscent = init::first_k(&ysdata, ksp);
    let sflat_eng = NativeEngine::default().with_strategy(Strategy::Flat);
    let snorm_eng = NativeEngine::default().with_strategy(Strategy::Norm);
    let sexp_eng = NativeEngine::default().with_strategy(Strategy::Auto);
    let mut yl = vec![0u32; ysdata.n()];
    let mut yd = vec![0f32; ysdata.n()];
    let mut set = BenchSet::new("assign sparse serving-scale (6k rows, k=1024)", opts);
    set.bench("flat scan 1 thread", || {
        sflat_eng.assign(&ysdata, Sel::Range(0, ysdata.n()), &yscent, &Pool::new(1), &mut yl, &mut yd)
    });
    set.bench("norm-prune 1 thread", || {
        snorm_eng.assign(&ysdata, Sel::Range(0, ysdata.n()), &yscent, &Pool::new(1), &mut yl, &mut yd)
    });
    set.bench("exponion 1 thread", || {
        sexp_eng.assign(&ysdata, Sel::Range(0, ysdata.n()), &yscent, &Pool::new(1), &mut yl, &mut yd)
    });
    let st_flat = set.get("flat scan 1 thread").unwrap().min_secs();
    let st_norm = set.get("norm-prune 1 thread").unwrap().min_secs();
    let st_exp = set.get("exponion 1 thread").unwrap().min_secs();
    let (sp, se) = sexp_eng.strategy_tally().snapshot()[2];
    let sparse_reduction = if se > 0 { sp as f64 * ksp as f64 / se as f64 } else { 1.0 };
    println!(
        "     → sparse k=1024: exponion {:.2}x vs flat, {:.2}x vs norm-prune, {:.1}x fewer dot evals",
        st_flat / st_exp,
        st_norm / st_exp,
        sparse_reduction
    );
    report.meta("speedup_assign_sparse_k1024", json::num(st_flat / st_exp));
    report.meta("speedup_exp_vs_norm_sparse_k1024", json::num(st_norm / st_exp));
    report.meta("calc_reduction_sparse_k1024", json::num(sparse_reduction));
    report.push(set);

    // --- bound machinery ---------------------------------------------------
    let gdata = GaussianMixture::default_spec(8, 64).generate(10_000, 3);
    let gcent = init::first_k(&gdata, 50);
    let mut store = bounds::BoundStore::new(50);
    store.grow_to(10_000);
    let mut labels = vec![0u32; 10_000];
    for i in 0..10_000 {
        labels[i] = bounds::full_assign_fill(&gdata, i, &gcent, store.row_mut(i)).label;
    }
    let mut set = BenchSet::new("tb bound machinery (10k pts, k=50)", opts);
    set.bench("tb_point_step pass (stationary)", || {
        let mut calcs = 0u64;
        for i in 0..10_000 {
            calcs += bounds::tb_point_step(&gdata, i, &gcent, store.row_mut(i), labels[i])
                .dist_calcs;
        }
        calcs
    });
    set.bench("screen pass (clean)", || {
        let mut dirty = 0u32;
        for i in 0..10_000 {
            let mut row = store.row(i).to_vec();
            dirty += bounds::screen(&mut row, &gcent.p, labels[i], 0.0) as u32;
        }
        dirty
    });
    set.bench("full_assign_fill pass (no bounds)", || {
        let mut row = vec![0f32; 50];
        let mut acc = 0u64;
        for i in 0..10_000 {
            acc += bounds::full_assign_fill(&gdata, i, &gcent, &mut row).dist_calcs;
        }
        acc
    });
    let screened = set.get("screen pass (clean)").unwrap().min_secs();
    let full = set.get("full_assign_fill pass (no bounds)").unwrap().min_secs();
    println!(
        "     → screen is {:.0}x cheaper than full recompute (must be ≫1 for the tile path to pay)",
        full / screened
    );
    report.push(set);

    // --- stats merge -------------------------------------------------------
    let mut set = BenchSet::new("coordinator merge (k=64, d=784)", opts);
    set.bench("merge 8 SuffStats deltas", || {
        use nmbkm::coordinator::merge::Mergeable;
        let mut total = nmbkm::kmeans::state::SuffStats::zeros(64, 784);
        for _ in 0..8 {
            total.merge(nmbkm::kmeans::state::SuffStats::zeros(64, 784));
        }
        total.v[0]
    });
    report.push(set);

    // --- observability primitives -----------------------------------------
    // the costs the serve layer pays per request/per chunk: one sharded
    // counter add, one histogram record, and the disabled-timer path
    let mut set = BenchSet::new("obs primitives", opts);
    let counter = nmbkm::obs::registry().counter("bench_obs_counter_total", &[]);
    set.bench("counter add x1M", || {
        for _ in 0..1_000_000 {
            counter.add(std::hint::black_box(1));
        }
        counter.get()
    });
    let hist = nmbkm::obs::registry().histogram("bench_obs_hist_seconds", &[]);
    set.bench("histogram record x1M", || {
        for i in 0..1_000_000u64 {
            hist.record_nanos(std::hint::black_box(i.wrapping_mul(2654435761) >> 16));
        }
        hist.count()
    });
    nmbkm::obs::set_enabled(false);
    set.bench("disabled timer start+observe x1M", || {
        let mut alive = 0u64;
        for _ in 0..1_000_000 {
            let t = nmbkm::obs::Timer::start();
            t.observe(&hist);
            alive += 1;
        }
        alive
    });
    nmbkm::obs::set_enabled(true);
    report.push(set);

    report.write(&json_path).expect("failed to write bench report");
    println!("\nmicro_hotpaths done");
}
