//! Sculley's Mini-Batch k-means (`mb`), paper §2.1.
//!
//! Two *identical-output* formulations are provided because Table 1 of
//! the paper is about exactly this implementation difference
//! (Supp. A.1):
//!
//! * [`Formulation::Alg1`] — the WWW'10 original: per-sample convex
//!   update `C(a) ← (1−1/v)·C(a) + x/v`. Each step rescales a (dense!)
//!   centroid: O(d) per sample regardless of datapoint sparsity.
//! * [`Formulation::Alg8`] — the cumulative-sum reformulation: maintain
//!   `S(j), v(j)`, set `C(j) = S(j)/v(j)` once per round — k centroid
//!   scalings instead of b, decisive when datapoints are much sparser
//!   than centroids (φ ≫ 1).
//!
//! Sampling follows the paper's own implementation note (§4 footnote):
//! cycle through the data with per-epoch reshuffling rather than
//! uniform sampling.

use crate::kmeans::assign::Sel;
use crate::kmeans::state::{Assignments, Centroids, SuffStats};
use crate::kmeans::{Clusterer, Ctx, RoundInfo};

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Formulation {
    Alg1,
    Alg8,
}

pub struct MiniBatch {
    pub(crate) cent: Centroids,
    pub(crate) stats: SuffStats,
    /// previous labels, for `changed` accounting only (mb never corrects
    /// old contributions — that is mb-f's fix).
    assign: Assignments,
    order: Vec<usize>,
    cursor: usize,
    b: usize,
    formulation: Formulation,
}

impl MiniBatch {
    pub fn new(cent: Centroids, n: usize, b: usize, formulation: Formulation) -> Self {
        let k = cent.k();
        let d = cent.d();
        Self {
            cent,
            stats: SuffStats::zeros(k, d),
            assign: Assignments::new(n),
            order: (0..n).collect(),
            cursor: 0,
            b: b.min(n),
            formulation,
        }
    }

    /// Next `b` indices, cycling with reshuffle at epoch boundaries.
    fn next_batch(&mut self, rng: &mut crate::util::rng::Pcg64) -> Vec<usize> {
        let n = self.order.len();
        let mut out = Vec::with_capacity(self.b);
        for _ in 0..self.b {
            if self.cursor == 0 {
                rng.shuffle(&mut self.order);
            }
            out.push(self.order[self.cursor]);
            self.cursor = (self.cursor + 1) % n;
        }
        out
    }
}

impl Clusterer for MiniBatch {
    fn round(&mut self, ctx: &mut Ctx) -> RoundInfo {
        let k = self.cent.k();
        let idx = self.next_batch(&mut ctx.rng);
        let mut lbl = vec![0u32; idx.len()];
        let mut d2 = vec![0f32; idx.len()];
        // assignment step (start-of-round centroids, both formulations)
        let calcs = ctx.engine.assign(
            ctx.data,
            Sel::List(&idx),
            &self.cent,
            &ctx.pool,
            &mut lbl,
            &mut d2,
        );
        let mut changed = 0u64;
        for (t, &i) in idx.iter().enumerate() {
            if self.assign.seen(i) && self.assign.label[i] != lbl[t] {
                changed += 1;
            }
            self.assign.label[i] = lbl[t];
            self.assign.dist2[i] = d2[t];
        }
        match self.formulation {
            Formulation::Alg8 => {
                // cumulative S/v, one centroid scaling per cluster
                let delta = crate::kmeans::par_add_stats(
                    ctx.data,
                    Sel::List(&idx),
                    &lbl,
                    &d2,
                    k,
                    &ctx.pool,
                );
                crate::coordinator::merge::Mergeable::merge(
                    &mut self.stats,
                    delta,
                );
                self.stats.update_centroids(&mut self.cent);
            }
            Formulation::Alg1 => {
                // per-sample convex updates (inherently sequential);
                // v/S still tracked so both formulations expose the
                // same statistics to tests.
                let d = self.cent.d();
                let mut xrow = vec![0f32; d];
                let old_c = self.cent.c.clone();
                for (t, &i) in idx.iter().enumerate() {
                    let j = lbl[t] as usize;
                    self.stats.add_point(ctx.data, i, lbl[t], d2[t]);
                    let v = self.stats.v[j];
                    ctx.data.write_row_dense(i, &mut xrow);
                    let row = self.cent.c.row_mut(j);
                    let eta = (1.0 / v) as f32;
                    for tcol in 0..d {
                        row[tcol] += eta * (xrow[tcol] - row[tcol]);
                    }
                }
                // refresh cached norms and displacements once per round
                for j in 0..k {
                    self.cent.norms[j] =
                        crate::linalg::dense::sq_norm(self.cent.c.row(j));
                    self.cent.p[j] = crate::linalg::dense::sq_dist(
                        old_c.row(j),
                        self.cent.c.row(j),
                    )
                    .sqrt();
                }
                // direct mutation above bypassed update_centroids —
                // refresh the revision so engine caches invalidate
                self.cent.touch();
            }
        }
        let train_mse =
            d2.iter().map(|&x| x as f64).sum::<f64>() / idx.len().max(1) as f64;
        RoundInfo {
            dist_calcs: calcs,
            bound_skips: 0,
            changed,
            batch: self.b,
            train_mse,
        }
    }

    fn centroids(&self) -> &Centroids {
        &self.cent
    }

    fn name(&self) -> String {
        "mb".into()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::gaussian::GaussianMixture;
    use crate::kmeans::assign::NativeEngine;
    use crate::kmeans::init;
    use crate::util::rng::Pcg64;

    /// Shared engine for test contexts (Ctx borrows it for 'static).
    fn test_engine() -> &'static NativeEngine {
        static E: std::sync::OnceLock<NativeEngine> = std::sync::OnceLock::new();
        E.get_or_init(NativeEngine::default)
    }

    fn ctx(data: &crate::data::Data) -> Ctx<'_> {
        Ctx {
            data,
            engine: test_engine(),
            pool: crate::coordinator::Pool::new(2),
            rng: Pcg64::new(0, 0),
        }
    }

    #[test]
    fn formulations_produce_same_clustering() {
        // Supp. A.1: Alg 1 and Alg 8 perform the exact same clustering
        // (up to floating-point noise).
        let data = GaussianMixture::default_spec(3, 6).generate(400, 4);
        let mut a = MiniBatch::new(init::first_k(&data, 3), 400, 64, Formulation::Alg1);
        let mut b = MiniBatch::new(init::first_k(&data, 3), 400, 64, Formulation::Alg8);
        let mut ca = ctx(&data);
        let mut cb = ctx(&data);
        for _ in 0..8 {
            a.round(&mut ca);
            b.round(&mut cb);
        }
        for j in 0..3 {
            for t in 0..6 {
                let x = a.cent.c.row(j)[t];
                let y = b.cent.c.row(j)[t];
                assert!(
                    (x - y).abs() < 1e-3 * (1.0 + y.abs()),
                    "centroid {j},{t}: alg1={x} alg8={y}"
                );
            }
        }
    }

    #[test]
    fn centroid_is_mean_of_all_ever_assigned() {
        let data = GaussianMixture::default_spec(2, 4).generate(100, 1);
        let mut mb =
            MiniBatch::new(init::first_k(&data, 2), 100, 32, Formulation::Alg8);
        let mut c = ctx(&data);
        for _ in 0..5 {
            mb.round(&mut c);
        }
        // C(j) must equal S(j)/v(j) even after repeats (contamination
        // retained — that's mb's defining behaviour)
        for j in 0..2 {
            if mb.stats.v[j] > 0.0 {
                for t in 0..4 {
                    let expect = mb.stats.s_row(j)[t] / mb.stats.v[j];
                    assert!(
                        (mb.cent.c.row(j)[t] as f64 - expect).abs() < 1e-5,
                        "j={j} t={t}"
                    );
                }
            }
        }
        // 5 rounds × 32 > 100: some points must have been visited twice,
        // so cumulative v exceeds distinct count
        let total_v: f64 = mb.stats.v.iter().sum();
        assert_eq!(total_v, 5.0 * 32.0);
    }

    #[test]
    fn cycling_visits_everything_before_repeats() {
        let data = GaussianMixture::default_spec(2, 2).generate(50, 2);
        let mut mb =
            MiniBatch::new(init::first_k(&data, 2), 50, 25, Formulation::Alg8);
        let mut rng = Pcg64::new(9, 9);
        let b1 = mb.next_batch(&mut rng);
        let b2 = mb.next_batch(&mut rng);
        let all: std::collections::HashSet<usize> =
            b1.iter().chain(b2.iter()).cloned().collect();
        assert_eq!(all.len(), 50, "one epoch must cover the dataset");
    }
}
